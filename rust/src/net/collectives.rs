//! Collectives over the fabric: ring allreduce (reduce-scatter + allgather).
//!
//! This is the real NCCL-style schedule, executed with real messages: the
//! vector is split into `m` chunks; in `m-1` reduce-scatter rounds each
//! worker sends one chunk to its ring successor and accumulates the chunk
//! arriving from its predecessor; `m-1` allgather rounds then circulate the
//! fully-reduced chunks. Every worker ends with the exact elementwise mean.
//!
//! Must be called by **all m worker threads concurrently** (it is a
//! collective). Message ordering: chunks are routed by globally-unique
//! tags (`coll_id << 32 | round`) through [`Fabric::chunk_recv_tag`], so
//! rounds cannot interleave incorrectly even when elastic membership
//! changes a worker's ring predecessor between collectives.

use super::fabric::Fabric;
use crate::compress::Compressor;
use crate::util::Pool;

/// Chunk boundaries: split `len` into `m` nearly-equal ranges.
pub fn chunk_ranges(len: usize, m: usize) -> Vec<(usize, usize)> {
    (0..m).map(|i| chunk_range(len, m, i)).collect()
}

/// The `i`-th of `m` nearly-equal ranges over `len` — closed-form, so the
/// ring's hot loop needs no per-call boundary vector. Identical to
/// `chunk_ranges(len, m)[i]` (the first `len % m` chunks are one longer).
#[inline]
pub fn chunk_range(len: usize, m: usize, i: usize) -> (usize, usize) {
    let base = len / m;
    let rem = len % m;
    let start = i * base + i.min(rem);
    (start, start + base + usize::from(i < rem))
}

/// In-place ring allreduce-mean of `x` across all `m` workers.
///
/// Returns the simulated completion time for this worker given `now` as
/// its entry time. (All workers converge to the same completion time in
/// the α-β model because each round is synchronous; we charge the
/// analytic ring cost — the real per-chunk message timings are implied.)
pub fn ring_allreduce_mean(
    fabric: &Fabric,
    worker: usize,
    x: &mut [f32],
    now: f64,
) -> f64 {
    let group: Vec<usize> = (0..fabric.m()).collect();
    ring_allreduce_mean_group(fabric, worker, &group, x, now, 0)
}

/// In-place ring allreduce-mean of `x` over an arbitrary subgroup of
/// workers — the elastic-membership primitive: the ring is rebuilt over
/// `group` (sorted, non-empty, must contain `worker`) and every member
/// ends with the exact elementwise mean over the group. Must be called by
/// **all group members** concurrently; non-members stay silent.
///
/// `coll_id` keys both the chunk-routing tags and the chaos layer's
/// per-collective delay stream (so all members charge the same extra
/// simulated time). Collectives that can be concurrently in flight —
/// consecutive boundaries around a membership change — must use distinct
/// ids; derive `coll_id` from the step or outer-boundary index and keep
/// it below 2^31 so the tag encoding `coll_id << 32 | round` never
/// collides with the rejoin-transfer tag space (bit 63).
pub fn ring_allreduce_mean_group(
    fabric: &Fabric,
    worker: usize,
    group: &[usize],
    x: &mut [f32],
    now: f64,
    coll_id: u64,
) -> f64 {
    ring_allreduce_mean_group_c(fabric, worker, group, x, now, coll_id, None)
}

/// [`ring_allreduce_mean_group`] with communication compression: when a
/// `codec` is given, every chunk message and the analytic completion-time
/// charge use the codec's wire size instead of raw `4·elems` bytes.
///
/// The *math* is unchanged — callers lossily transcode the input vector
/// (with error feedback) before entering the collective, and the ring
/// then averages those decoded contributions exactly, which is what a
/// real compressed allreduce delivers. `codec = None` (or the identity
/// codec) is bit-identical to the uncompressed path.
pub fn ring_allreduce_mean_group_c(
    fabric: &Fabric,
    worker: usize,
    group: &[usize],
    x: &mut [f32],
    now: f64,
    coll_id: u64,
    codec: Option<&dyn Compressor>,
) -> f64 {
    let mut pool = Pool::new();
    ring_allreduce_mean_group_p(
        fabric, worker, group, x, now, coll_id, codec, &mut pool,
    )
}

/// [`ring_allreduce_mean_group_c`] drawing its per-round send buffers
/// from `pool` and recycling every received chunk back into it, so a warm
/// pool makes the whole collective allocation-free: each round takes one
/// buffer out (shipped to the ring successor) and puts the one arriving
/// from the predecessor back — the buffer population is constant, it just
/// migrates around the ring. Bitwise-identical to the unpooled path.
#[allow(clippy::too_many_arguments)]
pub fn ring_allreduce_mean_group_p(
    fabric: &Fabric,
    worker: usize,
    group: &[usize],
    x: &mut [f32],
    now: f64,
    coll_id: u64,
    codec: Option<&dyn Compressor>,
    pool: &mut Pool<f32>,
) -> f64 {
    let n = group.len();
    assert!(n > 0, "empty collective group");
    let rank = group
        .iter()
        .position(|&g| g == worker)
        .expect("worker not in collective group");
    if n == 1 {
        return now;
    }
    let wire_of = |len: usize| -> u64 {
        match codec {
            Some(c) => c.wire_bytes(len),
            None => len as u64 * 4,
        }
    };
    let next = group[(rank + 1) % n];
    let tag_base = coll_id << 32;

    // Reduce-scatter: after round r, rank w owns the full sum of chunk
    // (w - r - 1 + ... ) — standard schedule: in round r, send chunk
    // (w - r) mod n, receive + accumulate chunk (w - r - 1) mod n.
    for r in 0..n - 1 {
        let send_idx = (rank + n - r) % n;
        let (s, e) = chunk_range(x.len(), n, send_idx);
        let mut buf = pool.take();
        buf.extend_from_slice(&x[s..e]);
        fabric.chunk_send_wire(
            worker,
            next,
            tag_base | r as u64,
            buf,
            wire_of(e - s),
        );
        let data = fabric.chunk_recv_tag(worker, tag_base | r as u64);
        let recv_idx = (rank + n - r - 1) % n;
        let (s, e) = chunk_range(x.len(), n, recv_idx);
        debug_assert_eq!(data.len(), e - s);
        for (dst, src) in x[s..e].iter_mut().zip(&data) {
            *dst += src;
        }
        pool.put(data);
    }
    // Allgather: circulate the reduced chunks.
    for r in 0..n - 1 {
        let send_idx = (rank + 1 + n - r) % n;
        let (s, e) = chunk_range(x.len(), n, send_idx);
        let mut buf = pool.take();
        buf.extend_from_slice(&x[s..e]);
        fabric.chunk_send_wire(
            worker,
            next,
            tag_base | (n + r) as u64,
            buf,
            wire_of(e - s),
        );
        let data = fabric.chunk_recv_tag(worker, tag_base | (n + r) as u64);
        let recv_idx = (rank + n - r) % n;
        let (s, e) = chunk_range(x.len(), n, recv_idx);
        x[s..e].copy_from_slice(&data);
        pool.put(data);
    }
    let inv_n = 1.0 / n as f32;
    for v in x.iter_mut() {
        *v *= inv_n;
    }
    // A synchronous ring round is gated by its slowest link: a ring
    // spanning more than one tier group charges the inter-group α-β
    // parameters (no-op without tiers — `cost_for_span` returns the flat
    // cost model, bit-identical to the pre-tier path).
    let mut done = now
        + fabric
            .cost_for_span(group)
            .allreduce_time_bytes(wire_of(x.len()), n);
    if let Some(plan) = fabric.chaos() {
        done += plan.collective_extra(coll_id, 2 * (n - 1));
    }
    done
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::run_workers;
    use crate::net::cost::CostModel;
    use crate::rng::Xoshiro256;
    use crate::testkit::{forall, WorkerVecs};
    use crate::util::allclose;

    fn allreduce_all(m: usize, vecs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let fabric = Fabric::new(m, CostModel::free());
        run_workers(m, |w| {
            let mut x = vecs[w].clone();
            ring_allreduce_mean(&fabric, w, &mut x, 0.0);
            x
        })
    }

    #[test]
    fn chunk_ranges_cover_exactly() {
        for (len, m) in [(10, 3), (7, 7), (5, 8), (0, 2), (100, 1)] {
            let r = chunk_ranges(len, m);
            assert_eq!(r.len(), m);
            assert_eq!(r[0].0, 0);
            assert_eq!(r[m - 1].1, len);
            for w in r.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
        }
    }

    #[test]
    fn allreduce_computes_exact_mean() {
        let m = 4;
        let vecs: Vec<Vec<f32>> = (0..m)
            .map(|w| (0..10).map(|i| (w * 10 + i) as f32).collect())
            .collect();
        let want: Vec<f32> = (0..10)
            .map(|i| {
                (0..m).map(|w| (w * 10 + i) as f32).sum::<f32>() / m as f32
            })
            .collect();
        for out in allreduce_all(m, &vecs) {
            assert!(allclose(&out, &want, 1e-6, 1e-6));
        }
    }

    #[test]
    fn allreduce_single_worker_identity() {
        let out = allreduce_all(1, &[vec![1.0, 2.0, 3.0]]);
        assert_eq!(out[0], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn allreduce_len_smaller_than_m() {
        // 3 elements over 5 workers: some chunks are empty.
        let m = 5;
        let vecs: Vec<Vec<f32>> =
            (0..m).map(|w| vec![w as f32; 3]).collect();
        let want = vec![2.0f32; 3]; // mean of 0..4
        for out in allreduce_all(m, &vecs) {
            assert!(allclose(&out, &want, 1e-6, 1e-6));
        }
    }

    #[test]
    fn allreduce_property_equals_serial_mean() {
        forall(
            "ring-allreduce == serial mean",
            &WorkerVecs { m_range: (1, 9), d_range: (1, 67), scale: 2.0 },
            |vecs| {
                let m = vecs.len();
                let d = vecs[0].len();
                let mut want = vec![0.0f32; d];
                for v in vecs {
                    for (acc, &x) in want.iter_mut().zip(v) {
                        *acc += x;
                    }
                }
                for w in want.iter_mut() {
                    *w /= m as f32;
                }
                allreduce_all(m, vecs)
                    .iter()
                    .all(|out| allclose(out, &want, 1e-4, 1e-5))
            },
        );
    }

    #[test]
    fn allreduce_charges_ring_cost() {
        let m = 4;
        let cost = CostModel { latency_s: 0.001, bandwidth_bps: 1e6 };
        let fabric = Fabric::new(m, cost.clone());
        let done = run_workers(m, |w| {
            let mut x = vec![1.0f32; 1000];
            ring_allreduce_mean(&fabric, w, &mut x, 5.0)
        });
        let want = 5.0 + cost.allreduce_time(1000, m);
        for t in done {
            assert!((t - want).abs() < 1e-12);
        }
        // Bytes: 2(m-1) rounds × m senders × ~chunk bytes.
        assert!(fabric.bytes_sent() > 0);
    }

    #[test]
    fn codec_charges_compressed_bytes_without_touching_math() {
        use crate::compress::TopK;
        let m = 4;
        let d = 256;
        let cost = CostModel { latency_s: 1e-4, bandwidth_bps: 1e6 };
        let group: Vec<usize> = (0..m).collect();
        let run = |codec: Option<&dyn Compressor>| {
            let fabric = Fabric::new(m, cost.clone());
            let outs = run_workers(m, |w| {
                let mut x: Vec<f32> =
                    (0..d).map(|i| (w * d + i) as f32 * 0.01).collect();
                let t = ring_allreduce_mean_group_c(
                    &fabric, w, &group, &mut x, 0.0, 5, codec,
                );
                (x, t)
            });
            (outs, fabric.bytes_sent(), fabric.bytes_saved())
        };
        let (raw, raw_bytes, raw_saved) = run(None);
        let topk = TopK { frac: 0.25 };
        let (comp, comp_bytes, comp_saved) = run(Some(&topk));
        // The collective itself never alters values — lossiness happens
        // in the caller's transcode before entering the ring.
        for (a, b) in raw.iter().zip(&comp) {
            assert_eq!(a.0, b.0);
            // ... but the compressed run finishes sooner.
            assert!(b.1 < a.1, "{} !< {}", b.1, a.1);
        }
        assert!(comp_bytes < raw_bytes, "{comp_bytes} !< {raw_bytes}");
        assert_eq!(raw_saved, 0);
        assert!(comp_saved > 0);
    }

    #[test]
    fn group_allreduce_means_over_survivors_only() {
        // 5 workers, but only {0, 2, 3} form the ring; the others idle.
        let m = 5;
        let group = vec![0usize, 2, 3];
        let fabric = Fabric::new(m, CostModel::free());
        let outs = run_workers(m, |w| {
            let mut x = vec![w as f32; 7];
            if group.contains(&w) {
                ring_allreduce_mean_group(&fabric, w, &group, &mut x, 0.0, 9);
            }
            x
        });
        let want = vec![(0.0 + 2.0 + 3.0) / 3.0; 7];
        for &g in &group {
            assert!(allclose(&outs[g], &want, 1e-6, 1e-6), "worker {g}");
        }
        // Non-members are untouched.
        assert_eq!(outs[1], vec![1.0; 7]);
        assert_eq!(outs[4], vec![4.0; 7]);
    }

    #[test]
    fn group_allreduce_singleton_is_identity() {
        let fabric = Fabric::new(3, CostModel::free());
        let mut x = vec![5.0f32, 6.0];
        let t = ring_allreduce_mean_group(&fabric, 2, &[2], &mut x, 1.5, 0);
        assert_eq!(x, vec![5.0, 6.0]);
        assert_eq!(t, 1.5);
        assert_eq!(fabric.msgs_sent(), 0);
    }

    #[test]
    fn chaos_charges_collective_extra_uniformly() {
        use crate::net::chaos::{ChaosCfg, ChaosPlan};
        use std::sync::Arc;
        let m = 4;
        let cost = CostModel { latency_s: 0.001, bandwidth_bps: 1e6 };
        let cfg = ChaosCfg {
            seed: 21,
            delay_mean_s: 2e-3,
            ..ChaosCfg::default()
        };
        let plan = Arc::new(ChaosPlan::new(cfg, m, &cost).unwrap());
        let fabric = Fabric::with_chaos(m, cost.clone(), plan);
        let done = run_workers(m, |w| {
            let mut x = vec![1.0f32; 64];
            let group: Vec<usize> = (0..m).collect();
            ring_allreduce_mean_group(&fabric, w, &group, &mut x, 0.0, 3)
        });
        let base = cost.allreduce_time(64, m);
        for t in &done {
            assert!(*t > base, "chaos extra missing: {t} vs base {base}");
            assert_eq!(*t, done[0], "all members must agree on completion");
        }
        // Math is untouched: the mean of all-ones is one.
        let fabric2 = Fabric::new(m, cost);
        let outs = run_workers(m, |w| {
            let mut x = vec![1.0f32; 64];
            ring_allreduce_mean(&fabric2, w, &mut x, 0.0);
            x
        });
        assert!(outs.iter().all(|x| x.iter().all(|&v| v == 1.0)));
    }

    #[test]
    fn chunk_range_matches_chunk_ranges() {
        for (len, m) in [(10usize, 3usize), (7, 7), (5, 8), (0, 2),
                         (100, 1), (65536, 4)] {
            let r = chunk_ranges(len, m);
            for i in 0..m {
                assert_eq!(chunk_range(len, m, i), r[i], "len={len} i={i}");
            }
        }
    }

    #[test]
    fn pooled_allreduce_is_bitwise_identical_and_recycles() {
        let m = 4;
        let d = 37;
        let group: Vec<usize> = (0..m).collect();
        let fresh = {
            let fabric = Fabric::new(m, CostModel::free());
            run_workers(m, |w| {
                let mut rng = Xoshiro256::seed_from(w as u64 + 1);
                let mut x = vec![0.0f32; d];
                rng.fill_normal(&mut x, 1.0);
                for k in 0..3 {
                    ring_allreduce_mean_group_c(
                        &fabric, w, &group, &mut x, 0.0, k, None,
                    );
                }
                x
            })
        };
        let fabric = Fabric::new(m, CostModel::free());
        let pooled = run_workers(m, |w| {
            let mut rng = Xoshiro256::seed_from(w as u64 + 1);
            let mut x = vec![0.0f32; d];
            rng.fill_normal(&mut x, 1.0);
            let mut pool = Pool::new();
            for k in 0..3 {
                ring_allreduce_mean_group_p(
                    &fabric, w, &group, &mut x, 0.0, k, None, &mut pool,
                );
            }
            // Steady state: each collective returns as many buffers as
            // it takes, so the pool holds the recycled receives.
            assert!(pool.idle() > 0, "w{w}: nothing recycled");
            x
        });
        for (w, (a, b)) in fresh.iter().zip(&pooled).enumerate() {
            let a_bits: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
            let b_bits: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
            assert_eq!(a_bits, b_bits, "worker {w} diverged");
        }
    }

    #[test]
    fn repeated_allreduce_stays_consistent() {
        // Run 5 consecutive collectives; decaying by mean each time keeps
        // all workers in lockstep (catches cross-round chunk mixups).
        let m = 3;
        let fabric = Fabric::new(m, CostModel::free());
        let outs = run_workers(m, |w| {
            let mut rng = Xoshiro256::seed_from(w as u64);
            let mut x = vec![0.0f32; 32];
            rng.fill_normal(&mut x, 1.0);
            for _ in 0..5 {
                ring_allreduce_mean(&fabric, w, &mut x, 0.0);
            }
            x
        });
        for w in 1..m {
            assert!(allclose(&outs[w], &outs[0], 1e-6, 1e-7));
        }
    }
}
