//! The in-process message fabric: typed point-to-point messages between
//! worker threads with byte accounting and simulated-time stamps.

use crate::exec::Mailboxes;
use crate::net::cost::CostModel;
use std::sync::atomic::{AtomicU64, Ordering};

/// One gossip message (SGP/OSGP/D-PSGD payload).
#[derive(Clone, Debug)]
pub struct GossipMsg {
    pub from: usize,
    /// Gossip step the sender was at (for diagnostics; push-sum itself is
    /// correct for arbitrarily delayed messages).
    pub step: u64,
    /// Scaled parameters p·x.
    pub payload: Vec<f32>,
    /// Scaled push-sum weight p·w.
    pub weight: f64,
    /// Sender's simulated clock when the message left.
    pub send_time: f64,
}

/// Fabric over `m` workers: gossip mailboxes + a generic chunk channel for
/// collectives + counters.
pub struct Fabric {
    m: usize,
    gossip: Mailboxes<GossipMsg>,
    /// Collective lanes (ring allreduce chunks etc.).
    chunks: Mailboxes<(usize, Vec<f32>)>,
    pub cost: CostModel,
    bytes_sent: AtomicU64,
    msgs_sent: AtomicU64,
}

impl Fabric {
    pub fn new(m: usize, cost: CostModel) -> Self {
        Self {
            m,
            gossip: Mailboxes::new(m),
            chunks: Mailboxes::new(m),
            cost,
            bytes_sent: AtomicU64::new(0),
            msgs_sent: AtomicU64::new(0),
        }
    }

    pub fn m(&self) -> usize {
        self.m
    }

    fn account(&self, elems: usize) {
        self.bytes_sent
            .fetch_add(elems as u64 * 4, Ordering::Relaxed);
        self.msgs_sent.fetch_add(1, Ordering::Relaxed);
    }

    /// Send a gossip message; returns the simulated arrival time.
    pub fn gossip_send(&self, to: usize, msg: GossipMsg) -> f64 {
        let arrival = msg.send_time + self.cost.xfer_time(msg.payload.len());
        self.account(msg.payload.len());
        self.gossip.send(to, msg);
        arrival
    }

    /// Blocking gossip receive for `worker`. Returns the message and its
    /// simulated arrival time (send_time + transfer).
    pub fn gossip_recv(&self, worker: usize) -> (GossipMsg, f64) {
        let msg = self.gossip.recv(worker);
        let arrival = msg.send_time + self.cost.xfer_time(msg.payload.len());
        (msg, arrival)
    }

    /// Gossip receive with a timeout (OSGP staleness-bound path): `None`
    /// if nothing arrives — e.g. when peers already finished their run.
    pub fn gossip_recv_timeout(
        &self,
        worker: usize,
        timeout: std::time::Duration,
    ) -> Option<(GossipMsg, f64)> {
        let msg = self.gossip.recv_timeout(worker, timeout)?;
        let arrival = msg.send_time + self.cost.xfer_time(msg.payload.len());
        Some((msg, arrival))
    }

    /// Drain all gossip messages currently queued for `worker`
    /// (OSGP non-blocking receive path).
    pub fn gossip_drain(&self, worker: usize) -> Vec<(GossipMsg, f64)> {
        self.gossip
            .drain(worker)
            .into_iter()
            .map(|msg| {
                let arrival =
                    msg.send_time + self.cost.xfer_time(msg.payload.len());
                (msg, arrival)
            })
            .collect()
    }

    /// Collective lane: send one tagged chunk.
    pub(crate) fn chunk_send(&self, to: usize, tag: usize, data: Vec<f32>) {
        self.account(data.len());
        self.chunks.send(to, (tag, data));
    }

    /// Collective lane: blocking receive (chunks from a single predecessor
    /// arrive in FIFO order, so tags are sanity checks).
    pub(crate) fn chunk_recv(&self, worker: usize) -> (usize, Vec<f32>) {
        self.chunks.recv(worker)
    }

    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent.load(Ordering::Relaxed)
    }

    pub fn msgs_sent(&self) -> u64 {
        self.msgs_sent.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::run_workers;

    #[test]
    fn gossip_round_trip_and_accounting() {
        let f = Fabric::new(2, CostModel::free());
        let msg = GossipMsg {
            from: 0,
            step: 3,
            payload: vec![1.0, 2.0, 3.0],
            weight: 0.5,
            send_time: 1.0,
        };
        f.gossip_send(1, msg);
        let (got, arrival) = f.gossip_recv(1);
        assert_eq!(got.from, 0);
        assert_eq!(got.payload, vec![1.0, 2.0, 3.0]);
        assert_eq!(arrival, 1.0); // free network: arrival == send time
        assert_eq!(f.bytes_sent(), 12);
        assert_eq!(f.msgs_sent(), 1);
    }

    #[test]
    fn arrival_time_includes_transfer() {
        let cost = CostModel { latency_s: 1.0, bandwidth_bps: 4.0 };
        let f = Fabric::new(2, cost);
        let msg = GossipMsg {
            from: 0,
            step: 0,
            payload: vec![0.0; 2], // 8 bytes -> 2 s at 4 B/s
            weight: 1.0,
            send_time: 10.0,
        };
        let eta = f.gossip_send(1, msg);
        assert!((eta - 13.0).abs() < 1e-12);
        let (_, arrival) = f.gossip_recv(1);
        assert!((arrival - 13.0).abs() < 1e-12);
    }

    #[test]
    fn drain_returns_all_pending() {
        let f = Fabric::new(2, CostModel::free());
        for step in 0..3 {
            f.gossip_send(
                0,
                GossipMsg {
                    from: 1,
                    step,
                    payload: vec![step as f32],
                    weight: 0.5,
                    send_time: 0.0,
                },
            );
        }
        let msgs = f.gossip_drain(0);
        assert_eq!(msgs.len(), 3);
        assert!(f.gossip_drain(0).is_empty());
    }

    #[test]
    fn concurrent_gossip_all_to_all() {
        let f = Fabric::new(4, CostModel::free());
        run_workers(4, |i| {
            for to in 0..4 {
                if to != i {
                    f.gossip_send(
                        to,
                        GossipMsg {
                            from: i,
                            step: 0,
                            payload: vec![i as f32],
                            weight: 1.0,
                            send_time: 0.0,
                        },
                    );
                }
            }
            let mut froms: Vec<usize> =
                (0..3).map(|_| f.gossip_recv(i).0.from).collect();
            froms.sort_unstable();
            let expect: Vec<usize> =
                (0..4).filter(|&x| x != i).collect();
            assert_eq!(froms, expect);
        });
        assert_eq!(f.msgs_sent(), 12);
    }
}
