//! The in-process message fabric: typed point-to-point messages between
//! worker threads with byte accounting and simulated-time stamps.
//!
//! A fabric built with [`Fabric::with_chaos`] routes every gossip message
//! through a [`ChaosPlan`]: the plan's deterministic per-link delay,
//! retransmit, and bounded-reordering charges are stamped onto the message
//! at send time, so both endpoints observe the same simulated arrival.
//! Chaos never changes what is delivered — only when (in simulated time).
//!
//! The transport underneath is chosen by [`ExecMode`]
//! ([`Fabric::with_mode`]): the default `sim` backend queues through mpsc
//! mailboxes, the `threaded` backend through per-link spin channels built
//! for real wall-clock throughput. Every simulated-time and byte-
//! accounting computation is identical across backends — a `threaded` run
//! reports the same `sim_time`, `bytes_*` and (where merge order is
//! fixed) bit-identical parameters as its `sim` twin, while its
//! `wall_time` measures what the hardware actually did.

use crate::exec::{ExecMode, Lanes};
use crate::net::chaos::ChaosPlan;
use crate::net::cost::CostModel;
use crate::topology::{Groups, TierTree};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// N-level link context: a [`TierTree`] over the workers plus one α-β
/// cost model per tier above the leaves. With tiers installed the fabric
/// charges every transfer the cost of the link it actually crosses —
/// `Fabric::cost` inside a leaf group, `links[l-1]` for a hop first
/// joined at tier `l`, `links.last()` for a top-level crossing — and
/// tallies leaf-crossing wire bytes separately
/// ([`Fabric::bytes_inter`]), so hierarchical runs and flat runs on the
/// same tiered cluster are compared honestly.
///
/// The historical two-tier setup ([`Fabric::set_tiers`]) is exactly the
/// depth-1 tree with a single link model: same matches, same charges,
/// bit for bit.
#[derive(Clone)]
pub struct Tiers {
    pub tree: Arc<TierTree>,
    /// Cost models of the slow links, one per tier: `links[l-1]` governs
    /// transfers first joined at tier `l` (`Fabric::cost` stays the fast
    /// intra-leaf-group model); `links[depth-1]` also covers pairs that
    /// share no group at any tier. Invariant: `links.len() == depth`.
    pub links: Vec<CostModel>,
}

/// One gossip message (SGP/OSGP/D-PSGD payload).
#[derive(Clone, Debug)]
pub struct GossipMsg {
    pub from: usize,
    /// Gossip step the sender was at (for diagnostics; push-sum itself is
    /// correct for arbitrarily delayed messages).
    pub step: u64,
    /// Scaled parameters p·x.
    pub payload: Vec<f32>,
    /// Scaled push-sum weight p·w.
    pub weight: f64,
    /// Sender's simulated clock when the message left.
    pub send_time: f64,
}

/// Fabric over `m` workers: gossip mailboxes + a generic chunk channel for
/// collectives + counters.
///
/// Byte accounting is *wire-honest*: every send carries the number of
/// bytes a real transport would move for it (the compressed size when a
/// [`crate::compress::Compressor`] is active; `4·elems` otherwise), and
/// [`Fabric::bytes_sent`] sums exactly those. [`Fabric::bytes_raw`] keeps
/// the uncompressed `4·elems` total so [`Fabric::bytes_saved`] reports
/// what compression actually bought.
pub struct Fabric {
    m: usize,
    mode: ExecMode,
    /// Gossip lane: messages tagged with their chaos extra-delay (0.0 on a
    /// calm fabric) and wire byte count, so receive-side arrival math
    /// matches the send side.
    gossip: Lanes<(GossipMsg, f64, u64)>,
    /// Collective lanes (ring allreduce chunks, rejoin transfers). Tags
    /// are globally-unique routing keys — see [`Fabric::chunk_recv_tag`].
    chunks: Lanes<(u64, Vec<f32>)>,
    /// Per-worker stash of early chunks (only the owning worker thread
    /// touches its slot; the mutex is for the `&self` API).
    chunk_stash: Vec<Mutex<Vec<(u64, Vec<f32>)>>>,
    /// Real nanoseconds each worker spent blocked inside fabric receives
    /// (only worker w's thread touches slot w). Measured identically in
    /// both exec modes, so threaded-vs-sim comparisons are apples to
    /// apples; feeds `TrainResult::comm_wall_time`.
    comm_wait_ns: Vec<AtomicU64>,
    pub cost: CostModel,
    tiers: Option<Tiers>,
    chaos: Option<Arc<ChaosPlan>>,
    bytes_sent: AtomicU64,
    bytes_raw: AtomicU64,
    bytes_inter: AtomicU64,
    msgs_sent: AtomicU64,
}

impl Fabric {
    pub fn new(m: usize, cost: CostModel) -> Self {
        Self::with_mode(m, cost, ExecMode::Sim)
    }

    /// A fabric on an explicit execution backend. `Sim` is what
    /// [`Fabric::new`] builds; `Threaded` swaps the transport for the
    /// per-link spin channels while keeping every cost/accounting
    /// computation bit-identical.
    pub fn with_mode(m: usize, cost: CostModel, mode: ExecMode) -> Self {
        Self {
            m,
            mode,
            gossip: Lanes::new(mode, m),
            chunks: Lanes::new(mode, m),
            chunk_stash: (0..m).map(|_| Mutex::new(Vec::new())).collect(),
            comm_wait_ns: (0..m).map(|_| AtomicU64::new(0)).collect(),
            cost,
            tiers: None,
            chaos: None,
            bytes_sent: AtomicU64::new(0),
            bytes_raw: AtomicU64::new(0),
            bytes_inter: AtomicU64::new(0),
            msgs_sent: AtomicU64::new(0),
        }
    }

    /// A fabric whose messages are degraded by a deterministic chaos plan.
    /// Chaos is sim-only: its delays are simulated-time charges that the
    /// threaded backend would measure right past.
    pub fn with_chaos(m: usize, cost: CostModel, plan: Arc<ChaosPlan>) -> Self {
        let mut f = Self::new(m, cost);
        f.chaos = Some(plan);
        f
    }

    /// Install a two-tier link context (worker partition + inter-group
    /// cost model). Every subsequent send is charged per the link it
    /// crosses and inter-group wire bytes are tallied separately. This is
    /// the depth-1 special case of [`Fabric::set_tier_tree`].
    pub fn set_tiers(&mut self, groups: Arc<Groups>, inter: CostModel) {
        assert_eq!(groups.m(), self.m, "tier partition must cover m workers");
        self.tiers = Some(Tiers {
            tree: Arc::new(TierTree::from_groups(groups)),
            links: vec![inter],
        });
    }

    /// Install an N-level tier tree with one cost model per tier:
    /// `links[l-1]` is charged to transfers whose endpoints are first
    /// joined at tier `l` (and `links[depth-1]` to pairs sharing no group
    /// at any tier); hops inside a leaf group keep the fast
    /// `Fabric::cost`.
    pub fn set_tier_tree(&mut self, tree: Arc<TierTree>, links: Vec<CostModel>) {
        assert_eq!(tree.m(), self.m, "tier tree must cover m workers");
        assert_eq!(
            links.len(),
            tree.depth(),
            "need one link cost model per tier (depth {})",
            tree.depth()
        );
        self.tiers = Some(Tiers { tree, links });
    }

    pub fn m(&self) -> usize {
        self.m
    }

    /// The execution backend this fabric runs on.
    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    /// Real seconds `worker` has spent blocked inside fabric receives.
    pub fn comm_wait_s(&self, worker: usize) -> f64 {
        self.comm_wait_ns[worker].load(Ordering::Relaxed) as f64 * 1e-9
    }

    fn note_wait(&self, worker: usize, t0: Instant) {
        self.comm_wait_ns[worker]
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn chaos(&self) -> Option<&ChaosPlan> {
        self.chaos.as_deref()
    }

    /// The installed leaf worker partition, when tiered accounting is on
    /// (tier 0 of the tree — what every two-level code path consumes).
    pub fn groups(&self) -> Option<&Groups> {
        self.tiers.as_ref().map(|t| &**t.tree.leaf())
    }

    /// The installed tier tree, when tiered accounting is on.
    pub fn tier_tree(&self) -> Option<&Arc<TierTree>> {
        self.tiers.as_ref().map(|t| &t.tree)
    }

    /// Cost model of the link `from -> to`: `cost` without tiers or
    /// inside a leaf group; `links[l-1]` when tier `l` is the first to
    /// join the endpoints; `links.last()` when no tier does.
    pub fn cost_for_link(&self, from: usize, to: usize) -> &CostModel {
        let Some(t) = &self.tiers else { return &self.cost };
        match t.tree.join_level(from, to) {
            Some(0) => &self.cost,
            Some(l) => &t.links[l - 1],
            None => t.links.last().expect("links.len() == depth >= 1"),
        }
    }

    /// Cost model governing a synchronous collective over `workers`: a
    /// ring round completes when its slowest transfer does, so a ring
    /// spanning tier-`l` groups is gated by the tier-`l` links (and one
    /// spanning the top tier by the slowest links of all).
    pub fn cost_for_span(&self, workers: &[usize]) -> &CostModel {
        let Some(t) = &self.tiers else { return &self.cost };
        match t.tree.span_level(workers) {
            Some(0) => &self.cost,
            Some(l) => &t.links[l - 1],
            None => t.links.last().expect("links.len() == depth >= 1"),
        }
    }

    fn account(&self, from: usize, to: usize, elems: usize, wire_bytes: u64) {
        self.bytes_sent.fetch_add(wire_bytes, Ordering::Relaxed);
        self.bytes_raw
            .fetch_add(elems as u64 * 4, Ordering::Relaxed);
        self.msgs_sent.fetch_add(1, Ordering::Relaxed);
        if let Some(t) = &self.tiers {
            // bytes_inter keeps its historical meaning: wire bytes that
            // left a leaf group, whatever deeper tier the hop joined at.
            if t.tree.leaf().is_inter(from, to) {
                self.bytes_inter.fetch_add(wire_bytes, Ordering::Relaxed);
            }
        }
    }

    fn arrival(
        &self,
        msg: &GossipMsg,
        to: usize,
        extra: f64,
        wire_bytes: u64,
    ) -> f64 {
        msg.send_time
            + self.cost_for_link(msg.from, to).xfer_time_bytes(wire_bytes)
            + extra
    }

    /// Send a gossip message; returns the simulated arrival time
    /// (send_time + transfer + any chaos delay/retransmit charge).
    pub fn gossip_send(&self, to: usize, msg: GossipMsg) -> f64 {
        let wire = msg.payload.len() as u64 * 4;
        self.gossip_send_wire(to, msg, wire)
    }

    /// Send a gossip message whose payload has already been passed
    /// through a compressor: `wire_bytes` is the honest compressed size,
    /// charged to the transfer time, the chaos retransmit accounting and
    /// [`Fabric::bytes_sent`] (the payload itself carries the decoded
    /// values).
    pub fn gossip_send_wire(
        &self,
        to: usize,
        msg: GossipMsg,
        wire_bytes: u64,
    ) -> f64 {
        let extra = match &self.chaos {
            Some(plan) => plan.link_extra(msg.from, to, wire_bytes),
            None => 0.0,
        };
        let arrival = self.arrival(&msg, to, extra, wire_bytes);
        let from = msg.from;
        self.account(from, to, msg.payload.len(), wire_bytes);
        self.gossip.send(from, to, (msg, extra, wire_bytes));
        arrival
    }

    /// Blocking gossip receive for `worker`. Returns the message and its
    /// simulated arrival time (send_time + transfer + chaos extra).
    pub fn gossip_recv(&self, worker: usize) -> (GossipMsg, f64) {
        let t0 = Instant::now();
        let (msg, extra, wire) = self.gossip.recv(worker);
        self.note_wait(worker, t0);
        let arrival = self.arrival(&msg, worker, extra, wire);
        (msg, arrival)
    }

    /// Gossip receive with a timeout (OSGP staleness-bound path): `None`
    /// if nothing arrives — e.g. when peers already finished their run.
    pub fn gossip_recv_timeout(
        &self,
        worker: usize,
        timeout: std::time::Duration,
    ) -> Option<(GossipMsg, f64)> {
        let t0 = Instant::now();
        let got = self.gossip.recv_timeout(worker, timeout);
        self.note_wait(worker, t0);
        let (msg, extra, wire) = got?;
        let arrival = self.arrival(&msg, worker, extra, wire);
        Some((msg, arrival))
    }

    /// Drain all gossip messages currently queued for `worker`
    /// (OSGP non-blocking receive path).
    pub fn gossip_drain(&self, worker: usize) -> Vec<(GossipMsg, f64)> {
        self.gossip
            .drain(worker)
            .into_iter()
            .map(|(msg, extra, wire)| {
                let arrival = self.arrival(&msg, worker, extra, wire);
                (msg, arrival)
            })
            .collect()
    }

    /// Collective lane: send one tagged chunk. Tags must be globally
    /// unique per logical message (collective id × round, or a rejoin
    /// transfer id) so receivers can route them. `from` feeds the
    /// two-tier byte accounting (which link did this chunk cross).
    pub(crate) fn chunk_send(
        &self,
        from: usize,
        to: usize,
        tag: u64,
        data: Vec<f32>,
    ) {
        let wire = data.len() as u64 * 4;
        self.chunk_send_wire(from, to, tag, data, wire);
    }

    /// Collective-lane send with an explicit wire byte count (compressed
    /// collectives charge their true size; the chunk still carries the
    /// decoded f32 values).
    pub(crate) fn chunk_send_wire(
        &self,
        from: usize,
        to: usize,
        tag: u64,
        data: Vec<f32>,
        wire_bytes: u64,
    ) {
        self.account(from, to, data.len(), wire_bytes);
        self.chunks.send(from, to, (tag, data));
    }

    /// Control-plane send on the collective lane: routes a tagged chunk
    /// like [`Fabric::chunk_send`] but bypasses the byte/message
    /// accounting and charges no transfer cost. Reserved for zero-cost
    /// bookkeeping — the boundary arrival-stamp exchange's ~12 B
    /// payloads, whose barrier the subsequent data transfers already
    /// pay for — so control traffic never perturbs `bytes_sent` /
    /// `bytes_raw` / `msgs_sent` relative to the blocking path.
    pub(crate) fn chunk_send_ctrl(
        &self,
        from: usize,
        to: usize,
        tag: u64,
        data: Vec<f32>,
    ) {
        self.chunks.send(from, to, (tag, data));
    }

    /// Collective lane: blocking receive of the chunk tagged `want`.
    ///
    /// With static membership every worker receives chunks from a single
    /// ring predecessor, whose mpsc channel is FIFO — arrival order always
    /// matches program order. Elastic membership breaks that: a worker's
    /// predecessor can change between collectives (a rejoiner inserted, a
    /// failed worker removed), so a fast new predecessor's first chunk can
    /// arrive while this worker still waits inside the previous collective
    /// (or for its rejoin transfer). Early chunks are stashed by tag and
    /// handed out when their collective comes up, which makes the math
    /// independent of thread interleaving.
    pub(crate) fn chunk_recv_tag(&self, worker: usize, want: u64) -> Vec<f32> {
        let mut stash = self.chunk_stash[worker].lock().unwrap();
        if let Some(pos) = stash.iter().position(|&(tag, _)| tag == want) {
            return stash.swap_remove(pos).1;
        }
        let t0 = Instant::now();
        loop {
            let (tag, data) = self.chunks.recv(worker);
            if tag == want {
                self.note_wait(worker, t0);
                return data;
            }
            stash.push((tag, data));
        }
    }

    /// Total bytes on the wire (compressed sizes when a codec is active).
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent.load(Ordering::Relaxed)
    }

    /// Total raw (uncompressed, 4 B/elem) bytes of everything sent.
    pub fn bytes_raw(&self) -> u64 {
        self.bytes_raw.load(Ordering::Relaxed)
    }

    /// Bytes compression kept off the wire (`raw - sent`, floored at 0).
    pub fn bytes_saved(&self) -> u64 {
        self.bytes_raw().saturating_sub(self.bytes_sent())
    }

    /// Wire bytes that crossed inter-group links (0 without tiers).
    pub fn bytes_inter(&self) -> u64 {
        self.bytes_inter.load(Ordering::Relaxed)
    }

    pub fn msgs_sent(&self) -> u64 {
        self.msgs_sent.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::run_workers;

    #[test]
    fn gossip_round_trip_and_accounting() {
        let f = Fabric::new(2, CostModel::free());
        let msg = GossipMsg {
            from: 0,
            step: 3,
            payload: vec![1.0, 2.0, 3.0],
            weight: 0.5,
            send_time: 1.0,
        };
        f.gossip_send(1, msg);
        let (got, arrival) = f.gossip_recv(1);
        assert_eq!(got.from, 0);
        assert_eq!(got.payload, vec![1.0, 2.0, 3.0]);
        assert_eq!(arrival, 1.0); // free network: arrival == send time
        assert_eq!(f.bytes_sent(), 12);
        assert_eq!(f.msgs_sent(), 1);
    }

    #[test]
    fn wire_send_charges_compressed_bytes() {
        let cost = CostModel { latency_s: 0.0, bandwidth_bps: 4.0 };
        let f = Fabric::new(2, cost);
        let msg = GossipMsg {
            from: 0,
            step: 0,
            payload: vec![0.0; 4], // raw 16 B, wire 8 B
            weight: 1.0,
            send_time: 0.0,
        };
        let eta = f.gossip_send_wire(1, msg, 8);
        assert!((eta - 2.0).abs() < 1e-12, "8 B at 4 B/s = 2 s, got {eta}");
        let (_, arrival) = f.gossip_recv(1);
        assert_eq!(arrival, eta, "both ends see the compressed transfer");
        assert_eq!(f.bytes_sent(), 8);
        assert_eq!(f.bytes_raw(), 16);
        assert_eq!(f.bytes_saved(), 8);
    }

    #[test]
    fn raw_sends_save_nothing() {
        let f = Fabric::new(2, CostModel::free());
        f.chunk_send(0, 1, 7, vec![1.0, 2.0]);
        assert_eq!(f.bytes_sent(), 8);
        assert_eq!(f.bytes_raw(), 8);
        assert_eq!(f.bytes_saved(), 0);
        assert_eq!(f.bytes_inter(), 0);
    }

    #[test]
    fn tiers_charge_per_link_and_tally_inter_bytes() {
        use crate::topology::Groups;
        // Groups {0,1} | {2,3}; intra free, inter 1 ms + 1 MB/s.
        let inter = CostModel { latency_s: 1e-3, bandwidth_bps: 1e6 };
        let mut f = Fabric::new(4, CostModel::free());
        f.set_tiers(
            Arc::new(Groups::parse("0-1|2-3", 4).unwrap()),
            inter.clone(),
        );
        let msg = |from: usize| GossipMsg {
            from,
            step: 0,
            payload: vec![0.0; 250], // 1000 B -> 1 ms serialization inter
            weight: 1.0,
            send_time: 0.0,
        };
        // Intra hop: free.
        let eta = f.gossip_send(1, msg(0));
        assert_eq!(eta, 0.0);
        assert_eq!(f.bytes_inter(), 0);
        // Inter hop: latency + bytes/bandwidth, tallied as inter.
        let eta = f.gossip_send(2, msg(0));
        assert!((eta - 2e-3).abs() < 1e-12, "{eta}");
        assert_eq!(f.bytes_inter(), 1000);
        // Receivers observe the same per-link arrival.
        let (_, a) = f.gossip_recv(1);
        assert_eq!(a, 0.0);
        let (_, a) = f.gossip_recv(2);
        assert!((a - 2e-3).abs() < 1e-12);
        // Chunk lane accounts tiers too.
        f.chunk_send(1, 3, 9, vec![0.0; 2]);
        assert_eq!(f.bytes_inter(), 1008);
        // Span queries drive the collective cost choice.
        assert_eq!(
            f.cost_for_span(&[2, 3]).latency_s,
            0.0,
            "intra span uses the fast model"
        );
        assert_eq!(f.cost_for_span(&[0, 2]).latency_s, inter.latency_s);
    }

    #[test]
    fn tier_tree_charges_per_join_level() {
        use crate::topology::TierTree;
        // Racks {0,1}{2,3}{4,5}{6,7}, pods {0-3}{4-7}: rack links free,
        // pod links 1 ms, datacenter links 10 ms.
        let pod = CostModel { latency_s: 1e-3, bandwidth_bps: f64::INFINITY };
        let dc = CostModel { latency_s: 1e-2, bandwidth_bps: f64::INFINITY };
        let tree = Arc::new(
            TierTree::parse("0-1|2-3|4-5|6-7;0-3|4-7", 8).unwrap(),
        );
        let mut f = Fabric::new(8, CostModel::free());
        f.set_tier_tree(tree, vec![pod.clone(), dc.clone()]);
        let msg = |from: usize| GossipMsg {
            from,
            step: 0,
            payload: vec![0.0; 4],
            weight: 1.0,
            send_time: 0.0,
        };
        // Same rack: free, not inter.
        assert_eq!(f.gossip_send(1, msg(0)), 0.0);
        assert_eq!(f.bytes_inter(), 0);
        // Same pod, different rack: pod latency; counts as inter (leaf
        // crossing), preserving the historical bytes_inter meaning.
        let eta = f.gossip_send(2, msg(0));
        assert!((eta - 1e-3).abs() < 1e-12, "{eta}");
        assert_eq!(f.bytes_inter(), 16);
        // Different pod: datacenter latency.
        let eta = f.gossip_send(4, msg(0));
        assert!((eta - 1e-2).abs() < 1e-12, "{eta}");
        assert_eq!(f.bytes_inter(), 32);
        // Span queries walk the same ladder.
        assert_eq!(f.cost_for_span(&[0, 1]).latency_s, 0.0);
        assert_eq!(f.cost_for_span(&[0, 2]).latency_s, pod.latency_s);
        assert_eq!(f.cost_for_span(&[0, 4]).latency_s, dc.latency_s);
        // The leaf partition is what groups() exposes.
        assert_eq!(f.groups().unwrap().g(), 4);
        assert_eq!(f.tier_tree().unwrap().depth(), 2);
    }

    #[test]
    fn depth_one_tree_matches_two_tier_setup() {
        use crate::topology::Groups;
        let inter = CostModel { latency_s: 1e-3, bandwidth_bps: 1e6 };
        let groups = Arc::new(Groups::parse("0-1|2-3", 4).unwrap());
        let mut a = Fabric::new(4, CostModel::free());
        a.set_tiers(Arc::clone(&groups), inter.clone());
        let mut b = Fabric::new(4, CostModel::free());
        b.set_tier_tree(
            Arc::new(crate::topology::TierTree::from_groups(groups)),
            vec![inter],
        );
        for from in 0..4 {
            for to in 0..4 {
                assert_eq!(
                    a.cost_for_link(from, to).latency_s,
                    b.cost_for_link(from, to).latency_s,
                    "{from}->{to}"
                );
            }
        }
        assert_eq!(
            a.cost_for_span(&[0, 2]).latency_s,
            b.cost_for_span(&[0, 2]).latency_s
        );
    }

    #[test]
    fn arrival_time_includes_transfer() {
        let cost = CostModel { latency_s: 1.0, bandwidth_bps: 4.0 };
        let f = Fabric::new(2, cost);
        let msg = GossipMsg {
            from: 0,
            step: 0,
            payload: vec![0.0; 2], // 8 bytes -> 2 s at 4 B/s
            weight: 1.0,
            send_time: 10.0,
        };
        let eta = f.gossip_send(1, msg);
        assert!((eta - 13.0).abs() < 1e-12);
        let (_, arrival) = f.gossip_recv(1);
        assert!((arrival - 13.0).abs() < 1e-12);
    }

    #[test]
    fn drain_returns_all_pending() {
        let f = Fabric::new(2, CostModel::free());
        for step in 0..3 {
            f.gossip_send(
                0,
                GossipMsg {
                    from: 1,
                    step,
                    payload: vec![step as f32],
                    weight: 0.5,
                    send_time: 0.0,
                },
            );
        }
        let msgs = f.gossip_drain(0);
        assert_eq!(msgs.len(), 3);
        assert!(f.gossip_drain(0).is_empty());
    }

    #[test]
    fn chaos_delay_shifts_arrival_on_both_ends() {
        use crate::net::chaos::{ChaosCfg, ChaosPlan};
        let cfg = ChaosCfg {
            seed: 11,
            delay_mean_s: 1e-3,
            ..ChaosCfg::default()
        };
        let cost = CostModel::free();
        let plan =
            Arc::new(ChaosPlan::new(cfg, 2, &cost).unwrap());
        let f = Fabric::with_chaos(2, cost, plan);
        let msg = GossipMsg {
            from: 0,
            step: 0,
            payload: vec![1.0; 4],
            weight: 1.0,
            send_time: 2.0,
        };
        let eta = f.gossip_send(1, msg);
        assert!(eta > 2.0, "chaos delay must push arrival past send time");
        let (_, arrival) = f.gossip_recv(1);
        assert_eq!(arrival, eta, "send and recv must agree on arrival");
    }

    #[test]
    fn chaos_drops_never_lose_messages() {
        use crate::net::chaos::{ChaosCfg, ChaosPlan};
        let cfg = ChaosCfg {
            seed: 5,
            drop_prob: 0.9,
            rto_s: 1e-3,
            ..ChaosCfg::default()
        };
        let cost = CostModel::free();
        let plan = Arc::new(ChaosPlan::new(cfg, 2, &cost).unwrap());
        let f = Fabric::with_chaos(2, cost, plan);
        for step in 0..20 {
            f.gossip_send(
                0,
                GossipMsg {
                    from: 1,
                    step,
                    payload: vec![step as f32],
                    weight: 0.5,
                    send_time: 0.0,
                },
            );
        }
        // Every message is delivered (drops only cost simulated time).
        assert_eq!(f.gossip_drain(0).len(), 20);
        assert!(f.chaos().unwrap().retransmits() > 0);
        // Goodput accounting is unchanged by retransmissions.
        assert_eq!(f.bytes_sent(), 20 * 4);
    }

    #[test]
    fn default_mode_is_sim() {
        let f = Fabric::new(2, CostModel::free());
        assert_eq!(f.mode(), crate::exec::ExecMode::Sim);
    }

    #[test]
    fn threaded_mode_same_arrival_and_accounting() {
        // The threaded transport must not perturb any simulated-time or
        // byte computation: replay the sim arithmetic checks on it.
        let cost = CostModel { latency_s: 1.0, bandwidth_bps: 4.0 };
        let f =
            Fabric::with_mode(2, cost, crate::exec::ExecMode::Threaded);
        assert_eq!(f.mode(), crate::exec::ExecMode::Threaded);
        let msg = GossipMsg {
            from: 0,
            step: 0,
            payload: vec![0.0; 2], // 8 bytes -> 2 s at 4 B/s
            weight: 1.0,
            send_time: 10.0,
        };
        let eta = f.gossip_send(1, msg);
        assert!((eta - 13.0).abs() < 1e-12);
        let (_, arrival) = f.gossip_recv(1);
        assert!((arrival - 13.0).abs() < 1e-12);
        assert_eq!(f.bytes_sent(), 8);
        assert_eq!(f.msgs_sent(), 1);
        f.chunk_send(0, 1, 7, vec![1.0, 2.0]);
        assert_eq!(f.chunk_recv_tag(1, 7), vec![1.0, 2.0]);
        assert_eq!(f.bytes_sent(), 16);
    }

    #[test]
    fn threaded_concurrent_gossip_all_to_all() {
        let f = Fabric::with_mode(
            4,
            CostModel::free(),
            crate::exec::ExecMode::Threaded,
        );
        run_workers(4, |i| {
            for to in 0..4 {
                if to != i {
                    f.gossip_send(
                        to,
                        GossipMsg {
                            from: i,
                            step: 0,
                            payload: vec![i as f32],
                            weight: 1.0,
                            send_time: 0.0,
                        },
                    );
                }
            }
            let mut froms: Vec<usize> =
                (0..3).map(|_| f.gossip_recv(i).0.from).collect();
            froms.sort_unstable();
            let expect: Vec<usize> =
                (0..4).filter(|&x| x != i).collect();
            assert_eq!(froms, expect);
        });
        assert_eq!(f.msgs_sent(), 12);
    }

    #[test]
    fn threaded_chunk_lane_routes_by_tag_across_threads() {
        let f = Fabric::with_mode(
            4,
            CostModel::free(),
            crate::exec::ExecMode::Threaded,
        );
        run_workers(4, |i| {
            let next = (i + 1) % 4;
            // Two rounds sent ahead of time: the receiver must pick tags
            // in its own order even when both are already queued.
            f.chunk_send(i, next, 100 + i as u64, vec![i as f32]);
            f.chunk_send(i, next, 200 + i as u64, vec![10.0 + i as f32]);
            let prev = (i + 3) % 4;
            let b = f.chunk_recv_tag(i, 200 + prev as u64);
            let a = f.chunk_recv_tag(i, 100 + prev as u64);
            assert_eq!(a, vec![prev as f32]);
            assert_eq!(b, vec![10.0 + prev as f32]);
        });
    }

    #[test]
    fn ctrl_sends_move_data_without_touching_accounting() {
        // The control plane (boundary arrival stamps) must be invisible
        // to every byte/message counter, or semisync runs could never be
        // byte-identical to the blocking path.
        let inter = CostModel { latency_s: 1e-3, bandwidth_bps: 1e6 };
        let mut f = Fabric::new(4, CostModel::free());
        f.set_tiers(
            Arc::new(crate::topology::Groups::parse("0-1|2-3", 4).unwrap()),
            inter,
        );
        f.chunk_send_ctrl(0, 2, 42, vec![1.0, 2.0, 3.0]);
        assert_eq!(f.chunk_recv_tag(2, 42), vec![1.0, 2.0, 3.0]);
        assert_eq!(f.bytes_sent(), 0);
        assert_eq!(f.bytes_raw(), 0);
        assert_eq!(f.msgs_sent(), 0);
        assert_eq!(f.bytes_inter(), 0, "even across the slow tier");
    }

    #[test]
    fn comm_wait_accumulates_on_blocking_recv() {
        let f = Fabric::new(2, CostModel::free());
        assert_eq!(f.comm_wait_s(0), 0.0);
        for step in 0..64 {
            f.gossip_send(
                0,
                GossipMsg {
                    from: 1,
                    step,
                    payload: vec![1.0],
                    weight: 1.0,
                    send_time: 0.0,
                },
            );
            f.gossip_recv(0);
        }
        // No-contention recvs still pay the (tiny, positive) measure.
        assert!(f.comm_wait_s(0) > 0.0);
        assert_eq!(f.comm_wait_s(1), 0.0);
    }

    #[test]
    fn concurrent_gossip_all_to_all() {
        let f = Fabric::new(4, CostModel::free());
        run_workers(4, |i| {
            for to in 0..4 {
                if to != i {
                    f.gossip_send(
                        to,
                        GossipMsg {
                            from: i,
                            step: 0,
                            payload: vec![i as f32],
                            weight: 1.0,
                            send_time: 0.0,
                        },
                    );
                }
            }
            let mut froms: Vec<usize> =
                (0..3).map(|_| f.gossip_recv(i).0.from).collect();
            froms.sort_unstable();
            let expect: Vec<usize> =
                (0..4).filter(|&x| x != i).collect();
            assert_eq!(froms, expect);
        });
        assert_eq!(f.msgs_sent(), 12);
    }
}
