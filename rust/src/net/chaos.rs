//! Deterministic chaos fabric: seeded network degradation for SlowMo runs.
//!
//! A [`ChaosPlan`] wraps the [`super::Fabric`] and injects, fully
//! deterministically given [`ChaosCfg::seed`]:
//!
//! - **per-link delays** — truncated-exponential extra latency per message,
//!   drawn from an [`crate::rng::stream`] keyed by `(seed, from, to, idx)`;
//! - **probabilistic drop with retransmit accounting** — a lost
//!   transmission attempt is retried after an RTO; the message always
//!   arrives (delivery semantics never change), the retries are charged as
//!   simulated time and counted in [`ChaosPlan::retransmits`];
//! - **bounded reordering** — within each window of `reorder_window`
//!   consecutive messages on a link, earlier sends receive the larger
//!   delays, so arrival *times* invert within the window (bounded
//!   overtaking in the simulated-time domain);
//! - **stragglers** — per-worker compute slowdown factors applied by the
//!   trainer to each inner step's compute charge;
//! - **fault windows** — elastic membership at SlowMo outer boundaries: a
//!   worker that is down for boundary `t` is excluded from the outer
//!   allreduce (the ring is rebuilt over survivors by
//!   [`super::ring_allreduce_mean_group`]); at its first live boundary it
//!   rejoins by pulling the averaged parameters from a survivor.
//!
//! Chaos never changes *what* is computed — only simulated time and the
//! retransmit counters — except for fault windows, which change membership
//! at outer boundaries. Two runs with the same seed are bit-identical.

use crate::exec::KeyedState;
use crate::net::cost::CostModel;
use crate::rng::stream;
use anyhow::{bail, ensure, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// One outage: `worker` is down for outer boundaries `fail_at <= t <
/// rejoin_at` and rejoins (pulling the averaged state) at boundary
/// `rejoin_at`. `rejoin_at == u64::MAX` means the worker never returns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultWindow {
    pub worker: usize,
    pub fail_at: u64,
    pub rejoin_at: u64,
}

/// Declarative chaos configuration (see the module docs). All knobs are
/// off by default; `seed` makes every sampled decision reproducible.
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosCfg {
    pub seed: u64,
    /// Mean extra per-message delay in seconds (exponential; 0 = off).
    pub delay_mean_s: f64,
    /// Truncation bound for sampled delays (0 = `10 * delay_mean_s`).
    pub delay_max_s: f64,
    /// Probability that one transmission attempt is lost.
    pub drop_prob: f64,
    /// Retransmission timeout charged per lost attempt
    /// (0 = [`CostModel::retransmit_timeout`]).
    pub rto_s: f64,
    /// Cap on counted retries per message.
    pub max_retries: u32,
    /// Bounded-reordering window (1 = no reordering).
    pub reorder_window: usize,
    /// `(worker, factor)` compute slowdowns; factor multiplies the
    /// simulated compute charge of every inner step on that worker.
    pub stragglers: Vec<(usize, f64)>,
    pub faults: Vec<FaultWindow>,
}

impl Default for ChaosCfg {
    fn default() -> Self {
        Self {
            seed: 0,
            delay_mean_s: 0.0,
            delay_max_s: 0.0,
            drop_prob: 0.0,
            rto_s: 0.0,
            max_retries: 3,
            reorder_window: 1,
            stragglers: Vec::new(),
            faults: Vec::new(),
        }
    }
}

fn parse_secs(s: &str) -> Result<f64, String> {
    let s = s.trim();
    let (num, mult) = if let Some(x) = s.strip_suffix("ms") {
        (x, 1e-3)
    } else if let Some(x) = s.strip_suffix("us") {
        (x, 1e-6)
    } else if let Some(x) = s.strip_suffix('s') {
        (x, 1.0)
    } else {
        (s, 1.0)
    };
    num.trim()
        .parse::<f64>()
        .map(|v| v * mult)
        .map_err(|_| format!("bad duration {s:?} (expected e.g. 2ms, 50us, 0.5s)"))
}

impl ChaosCfg {
    /// Parse one straggler entry, e.g. `"1:4.0"` (worker 1 runs 4x slower).
    pub fn parse_straggler(s: &str) -> Result<(usize, f64), String> {
        let (w, f) = s
            .split_once(':')
            .ok_or_else(|| format!("bad straggler {s:?} (expected worker:factor)"))?;
        let w = w
            .trim()
            .parse::<usize>()
            .map_err(|_| format!("bad straggler worker in {s:?}"))?;
        let f = f
            .trim()
            .parse::<f64>()
            .map_err(|_| format!("bad straggler factor in {s:?}"))?;
        Ok((w, f))
    }

    /// Parse one fault entry, e.g. `"2@3..5"` (worker 2 fails at outer
    /// boundary 3, rejoins at boundary 5) or `"2@3"` (never rejoins).
    pub fn parse_fault(s: &str) -> Result<FaultWindow, String> {
        let (w, rest) = s
            .split_once('@')
            .ok_or_else(|| format!("bad fault {s:?} (expected worker@fail..rejoin)"))?;
        let worker = w
            .trim()
            .parse::<usize>()
            .map_err(|_| format!("bad fault worker in {s:?}"))?;
        let (fail, rejoin) = match rest.split_once("..") {
            Some((a, b)) => (
                a.trim()
                    .parse::<u64>()
                    .map_err(|_| format!("bad fault boundary in {s:?}"))?,
                b.trim()
                    .parse::<u64>()
                    .map_err(|_| format!("bad rejoin boundary in {s:?}"))?,
            ),
            None => (
                rest.trim()
                    .parse::<u64>()
                    .map_err(|_| format!("bad fault boundary in {s:?}"))?,
                u64::MAX,
            ),
        };
        Ok(FaultWindow { worker, fail_at: fail, rejoin_at: rejoin })
    }
}

/// Spec-string form (the CLI's `--chaos` value): comma-separated `key=value`
/// pairs. Keys: `seed`, `delay`, `delay-max`, `drop`, `rto`, `retries`,
/// `reorder`, `straggle` (repeatable, `worker:factor`), `fault`
/// (repeatable, `worker@fail..rejoin`). Durations take `ms`/`us`/`s`
/// suffixes. An empty spec (or `"on"`) is a no-op plan with seed 0.
impl std::str::FromStr for ChaosCfg {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        let mut cfg = ChaosCfg::default();
        let s = s.trim();
        if s.is_empty() || s == "on" {
            return Ok(cfg);
        }
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = part.split_once('=').ok_or_else(|| {
                format!("chaos spec: expected key=value, got {part:?}")
            })?;
            let v = v.trim();
            match k.trim() {
                "seed" => {
                    cfg.seed = v
                        .parse()
                        .map_err(|_| format!("chaos seed: bad u64 {v:?}"))?;
                }
                "delay" => cfg.delay_mean_s = parse_secs(v)?,
                "delay-max" | "delay_max" => cfg.delay_max_s = parse_secs(v)?,
                "drop" => {
                    cfg.drop_prob = v
                        .parse()
                        .map_err(|_| format!("chaos drop: bad prob {v:?}"))?;
                }
                "rto" => cfg.rto_s = parse_secs(v)?,
                "retries" => {
                    cfg.max_retries = v
                        .parse()
                        .map_err(|_| format!("chaos retries: bad u32 {v:?}"))?;
                }
                "reorder" => {
                    cfg.reorder_window = v.parse().map_err(|_| {
                        format!("chaos reorder: bad window {v:?}")
                    })?;
                }
                "straggle" => {
                    let (w, f) = Self::parse_straggler(v)?;
                    if cfg.stragglers.iter().any(|&(ww, _)| ww == w) {
                        return Err(format!(
                            "chaos spec: duplicate straggle entry for \
                             worker {w}"
                        ));
                    }
                    cfg.stragglers.push((w, f));
                }
                "fault" => {
                    let fw = Self::parse_fault(v)?;
                    if cfg.faults.iter().any(|f| {
                        f.worker == fw.worker
                            && f.fail_at < fw.rejoin_at
                            && fw.fail_at < f.rejoin_at
                    }) {
                        return Err(format!(
                            "chaos spec: overlapping fault windows for \
                             worker {}",
                            fw.worker
                        ));
                    }
                    cfg.faults.push(fw);
                }
                other => {
                    return Err(format!(
                        "chaos spec: unknown key {other:?} (seed|delay|\
                         delay-max|drop|rto|retries|reorder|straggle|fault)"
                    ))
                }
            }
        }
        Ok(cfg)
    }
}

/// Per-link sampler state: next message index + the current reorder block.
struct LinkState {
    idx: u64,
    block: Vec<f64>,
}

/// A validated, executable chaos plan for `m` workers. Cheap to share
/// (`Arc`) between the fabric and the trainer.
pub struct ChaosPlan {
    cfg: ChaosCfg,
    m: usize,
    delay_max_s: f64,
    rto_s: f64,
    links: KeyedState<(usize, usize), LinkState>,
    retransmits: AtomicU64,
    retrans_bytes: AtomicU64,
}

impl ChaosPlan {
    /// Validate `cfg` against `m` workers and resolve defaults (`delay_max`,
    /// RTO from `cost`).
    pub fn new(cfg: ChaosCfg, m: usize, cost: &CostModel) -> Result<Self> {
        ensure!(m > 0, "chaos: m must be > 0");
        ensure!(
            (0.0..1.0).contains(&cfg.drop_prob),
            "chaos: drop_prob must be in [0, 1) (got {})",
            cfg.drop_prob
        );
        ensure!(
            cfg.delay_mean_s >= 0.0 && cfg.delay_mean_s.is_finite(),
            "chaos: delay_mean_s must be finite and >= 0"
        );
        ensure!(cfg.delay_max_s >= 0.0, "chaos: delay_max_s must be >= 0");
        ensure!(cfg.rto_s >= 0.0, "chaos: rto_s must be >= 0");
        ensure!(
            cfg.reorder_window >= 1,
            "chaos: reorder_window must be >= 1"
        );
        let mut straggling = vec![false; m];
        for &(w, f) in &cfg.stragglers {
            ensure!(w < m, "chaos: straggler worker {w} out of range (m={m})");
            ensure!(
                f.is_finite() && f > 0.0,
                "chaos: straggler factor for worker {w} must be > 0"
            );
            ensure!(
                !straggling[w],
                "chaos: duplicate straggler entry for worker {w}"
            );
            straggling[w] = true;
        }
        let mut by_worker: Vec<Vec<FaultWindow>> = vec![Vec::new(); m];
        for f in &cfg.faults {
            ensure!(
                f.worker < m,
                "chaos: fault worker {} out of range (m={m})",
                f.worker
            );
            ensure!(
                f.fail_at < f.rejoin_at,
                "chaos: fault for worker {} must fail before it rejoins",
                f.worker
            );
            by_worker[f.worker].push(*f);
        }
        for (w, wins) in by_worker.iter_mut().enumerate() {
            wins.sort_by_key(|f| f.fail_at);
            for pair in wins.windows(2) {
                ensure!(
                    pair[0].rejoin_at <= pair[1].fail_at,
                    "chaos: overlapping fault windows for worker {w}"
                );
            }
        }
        let plan = Self {
            delay_max_s: if cfg.delay_max_s > 0.0 {
                cfg.delay_max_s
            } else {
                10.0 * cfg.delay_mean_s
            },
            rto_s: if cfg.rto_s > 0.0 {
                cfg.rto_s
            } else {
                cost.retransmit_timeout()
            },
            links: KeyedState::new(),
            retransmits: AtomicU64::new(0),
            retrans_bytes: AtomicU64::new(0),
            m,
            cfg,
        };
        // Membership can only change at fault edges; every such boundary
        // needs at least one contributor to lead the group collective.
        let mut critical: Vec<u64> = Vec::new();
        for f in &plan.cfg.faults {
            critical.push(f.fail_at);
            if f.rejoin_at != u64::MAX {
                critical.push(f.rejoin_at);
            }
        }
        for &t in &critical {
            if plan.contributors(t).is_empty() {
                bail!(
                    "chaos: no live contributor at outer boundary {t} \
                     (every boundary needs at least one survivor)"
                );
            }
        }
        Ok(plan)
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn cfg(&self) -> &ChaosCfg {
        &self.cfg
    }

    pub fn has_faults(&self) -> bool {
        !self.cfg.faults.is_empty()
    }

    /// Compute slowdown for `worker` (1.0 = nominal speed).
    pub fn compute_factor(&self, worker: usize) -> f64 {
        self.cfg
            .stragglers
            .iter()
            .find(|&&(w, _)| w == worker)
            .map(|&(_, f)| f)
            .unwrap_or(1.0)
    }

    fn sample_delay(&self, rng: &mut crate::rng::Xoshiro256) -> f64 {
        if self.cfg.delay_mean_s <= 0.0 {
            return 0.0;
        }
        let u = rng.next_f64();
        (-self.cfg.delay_mean_s * (1.0 - u).ln()).min(self.delay_max_s)
    }

    fn sample_block(&self, from: u64, to: u64, block: u64) -> Vec<f64> {
        let w = self.cfg.reorder_window;
        let mut rng = stream(self.cfg.seed, "chaos.delay", from, to, block);
        let mut v: Vec<f64> = (0..w).map(|_| self.sample_delay(&mut rng)).collect();
        if w > 1 {
            // Bounded reordering: earlier sends in the window get the
            // larger delays, so arrival times invert within the window.
            v.sort_by(|a, b| b.total_cmp(a));
        }
        v
    }

    /// Count lost transmission attempts: geometric in `drop_prob`, capped
    /// at `max_retries`. Shared by the per-message and per-round charges
    /// so the two retry semantics can never diverge.
    fn sample_retries(&self, rng: &mut crate::rng::Xoshiro256) -> u32 {
        let mut n = 0;
        while n < self.cfg.max_retries && rng.next_f64() < self.cfg.drop_prob
        {
            n += 1;
        }
        n
    }

    fn sample_drops(&self, from: u64, to: u64, idx: u64) -> u32 {
        if self.cfg.drop_prob <= 0.0 {
            return 0;
        }
        let mut rng = stream(self.cfg.seed, "chaos.drop", from, to, idx);
        self.sample_retries(&mut rng)
    }

    /// Extra simulated seconds for the next message on link `from -> to`
    /// carrying `wire_bytes` bytes (the *compressed* size when a codec is
    /// active — retransmit accounting charges the true wire size).
    /// Advances the link's deterministic message counter and the
    /// retransmit accounting.
    pub fn link_extra(&self, from: usize, to: usize, wire_bytes: u64) -> f64 {
        if self.cfg.delay_mean_s <= 0.0 && self.cfg.drop_prob <= 0.0 {
            // Faults-only / no-op plans: skip the per-link counter lock on
            // the gossip hot path — with both knobs off the counter is
            // unobservable and every sample is 0.
            return 0.0;
        }
        let (idx, delay) = self.links.with_mut(
            (from, to),
            || LinkState { idx: 0, block: Vec::new() },
            |st| {
                let w = self.cfg.reorder_window as u64;
                let pos = (st.idx % w) as usize;
                if pos == 0 {
                    st.block =
                        self.sample_block(from as u64, to as u64, st.idx / w);
                }
                let d = st.block.get(pos).copied().unwrap_or(0.0);
                let idx = st.idx;
                st.idx += 1;
                (idx, d)
            },
        );
        let drops = self.sample_drops(from as u64, to as u64, idx);
        if drops > 0 {
            self.retransmits
                .fetch_add(u64::from(drops), Ordering::Relaxed);
            self.retrans_bytes
                .fetch_add(u64::from(drops) * wire_bytes, Ordering::Relaxed);
        }
        delay + f64::from(drops) * self.rto_s
    }

    /// Extra simulated seconds for a `rounds`-round collective identified
    /// by `coll_id`. Pure function of the plan seed, so every participant
    /// charges the same completion time (retransmit counters untouched —
    /// per-message accounting only applies to the point-to-point lanes).
    pub fn collective_extra(&self, coll_id: u64, rounds: usize) -> f64 {
        if self.cfg.delay_mean_s <= 0.0 && self.cfg.drop_prob <= 0.0 {
            return 0.0;
        }
        let mut rng = stream(self.cfg.seed, "chaos.coll", coll_id, 0, 0);
        let mut extra = 0.0;
        for _ in 0..rounds {
            extra += self.sample_delay(&mut rng);
            if self.cfg.drop_prob > 0.0 {
                extra += f64::from(self.sample_retries(&mut rng))
                    * self.rto_s;
            }
        }
        extra
    }

    /// Is `worker` down (mid-outage) at outer boundary `t`?
    pub fn down(&self, worker: usize, t: u64) -> bool {
        self.cfg
            .faults
            .iter()
            .any(|f| f.worker == worker && f.fail_at <= t && t < f.rejoin_at)
    }

    /// Is boundary `t` this worker's first live boundary after an outage
    /// (i.e. it must pull the averaged state instead of contributing)?
    pub fn is_rejoiner(&self, worker: usize, t: u64) -> bool {
        t > 0 && !self.down(worker, t) && self.down(worker, t - 1)
    }

    /// Workers contributing to the outer collective at boundary `t`
    /// (sorted; excludes down workers and rejoiners).
    pub fn contributors(&self, t: u64) -> Vec<usize> {
        (0..self.m)
            .filter(|&w| !self.down(w, t) && !self.is_rejoiner(w, t))
            .collect()
    }

    /// Workers rejoining at boundary `t` (sorted).
    pub fn rejoiners(&self, t: u64) -> Vec<usize> {
        (0..self.m).filter(|&w| self.is_rejoiner(w, t)).collect()
    }

    /// Contributor count at the previous boundary (`m` before the first).
    pub fn contributor_count_before(&self, t: u64) -> usize {
        if t == 0 {
            self.m
        } else {
            self.contributors(t - 1).len()
        }
    }

    /// Total retransmitted point-to-point messages so far.
    pub fn retransmits(&self) -> u64 {
        self.retransmits.load(Ordering::Relaxed)
    }

    /// Total retransmitted point-to-point bytes so far.
    pub fn retransmitted_bytes(&self) -> u64 {
        self.retrans_bytes.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(cfg: ChaosCfg, m: usize) -> ChaosPlan {
        ChaosPlan::new(cfg, m, &CostModel::ethernet_10g()).unwrap()
    }

    fn delays_cfg() -> ChaosCfg {
        ChaosCfg {
            seed: 7,
            delay_mean_s: 2e-3,
            drop_prob: 0.2,
            ..ChaosCfg::default()
        }
    }

    #[test]
    fn link_extra_is_deterministic_across_plans() {
        let a = plan(delays_cfg(), 4);
        let b = plan(delays_cfg(), 4);
        for i in 0..50 {
            assert_eq!(
                a.link_extra(0, 1, 16),
                b.link_extra(0, 1, 16),
                "msg {i}"
            );
        }
        assert_eq!(a.retransmits(), b.retransmits());
        assert_eq!(a.retransmitted_bytes(), b.retransmitted_bytes());
    }

    #[test]
    fn links_have_independent_streams() {
        let p = plan(delays_cfg(), 4);
        let a: Vec<f64> = (0..8).map(|_| p.link_extra(0, 1, 4)).collect();
        let b: Vec<f64> = (0..8).map(|_| p.link_extra(1, 0, 4)).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn zero_cfg_adds_nothing() {
        let p = plan(ChaosCfg::default(), 2);
        assert_eq!(p.link_extra(0, 1, 100), 0.0);
        assert_eq!(p.collective_extra(3, 6), 0.0);
        assert_eq!(p.retransmits(), 0);
        assert_eq!(p.compute_factor(0), 1.0);
    }

    #[test]
    fn delays_are_positive_and_truncated() {
        let cfg = ChaosCfg {
            seed: 1,
            delay_mean_s: 1e-3,
            delay_max_s: 5e-3,
            ..ChaosCfg::default()
        };
        let p = plan(cfg, 2);
        for _ in 0..200 {
            let d = p.link_extra(0, 1, 1);
            assert!((0.0..=5e-3).contains(&d), "delay {d}");
        }
    }

    #[test]
    fn reorder_window_inverts_within_blocks() {
        let cfg = ChaosCfg {
            seed: 3,
            delay_mean_s: 1e-3,
            reorder_window: 4,
            ..ChaosCfg::default()
        };
        let p = plan(cfg, 2);
        let d: Vec<f64> = (0..12).map(|_| p.link_extra(0, 1, 1)).collect();
        for block in d.chunks(4) {
            for pair in block.windows(2) {
                assert!(pair[0] >= pair[1], "block not descending: {block:?}");
            }
        }
    }

    #[test]
    fn drops_charge_time_and_count_retransmits() {
        let cfg = ChaosCfg {
            seed: 9,
            drop_prob: 0.9,
            rto_s: 1e-3,
            max_retries: 3,
            ..ChaosCfg::default()
        };
        let p = plan(cfg, 2);
        let mut total = 0.0;
        for _ in 0..50 {
            // 40-byte messages: retransmit accounting charges the true
            // wire size handed in (compressed when a codec is active).
            total += p.link_extra(0, 1, 40);
        }
        assert!(p.retransmits() > 0);
        assert_eq!(p.retransmitted_bytes(), p.retransmits() * 40);
        assert!((total - p.retransmits() as f64 * 1e-3).abs() < 1e-12);
    }

    #[test]
    fn collective_extra_same_for_all_callers() {
        let p = plan(delays_cfg(), 4);
        let a = p.collective_extra(5, 6);
        let b = p.collective_extra(5, 6);
        assert_eq!(a, b);
        assert!(a > 0.0);
        assert_ne!(a, p.collective_extra(6, 6));
    }

    #[test]
    fn membership_timeline_roles() {
        let cfg = ChaosCfg {
            faults: vec![FaultWindow { worker: 2, fail_at: 1, rejoin_at: 3 }],
            ..ChaosCfg::default()
        };
        let p = plan(cfg, 4);
        assert!(!p.down(2, 0) && !p.is_rejoiner(2, 0));
        assert!(p.down(2, 1) && p.down(2, 2));
        assert!(!p.down(2, 3) && p.is_rejoiner(2, 3));
        assert!(!p.is_rejoiner(2, 4));
        assert_eq!(p.contributors(0), vec![0, 1, 2, 3]);
        assert_eq!(p.contributors(1), vec![0, 1, 3]);
        assert_eq!(p.contributors(3), vec![0, 1, 3]);
        assert_eq!(p.rejoiners(3), vec![2]);
        assert_eq!(p.contributors(4), vec![0, 1, 2, 3]);
        assert_eq!(p.contributor_count_before(0), 4);
        assert_eq!(p.contributor_count_before(2), 3);
    }

    #[test]
    fn never_rejoining_worker_stays_out() {
        let cfg = ChaosCfg {
            faults: vec![FaultWindow {
                worker: 1,
                fail_at: 2,
                rejoin_at: u64::MAX,
            }],
            ..ChaosCfg::default()
        };
        let p = plan(cfg, 2);
        assert!(p.down(1, 1_000_000));
        assert_eq!(p.contributors(5), vec![0]);
    }

    #[test]
    fn validation_rejects_bad_plans() {
        let cost = CostModel::free();
        let bad_drop = ChaosCfg { drop_prob: 1.0, ..ChaosCfg::default() };
        assert!(ChaosPlan::new(bad_drop, 2, &cost).is_err());
        let bad_worker = ChaosCfg {
            stragglers: vec![(5, 2.0)],
            ..ChaosCfg::default()
        };
        assert!(ChaosPlan::new(bad_worker, 2, &cost).is_err());
        let bad_window = ChaosCfg {
            faults: vec![FaultWindow { worker: 0, fail_at: 3, rejoin_at: 3 }],
            ..ChaosCfg::default()
        };
        assert!(ChaosPlan::new(bad_window, 2, &cost).is_err());
        let overlap = ChaosCfg {
            faults: vec![
                FaultWindow { worker: 0, fail_at: 0, rejoin_at: 4 },
                FaultWindow { worker: 0, fail_at: 2, rejoin_at: 6 },
            ],
            ..ChaosCfg::default()
        };
        assert!(ChaosPlan::new(overlap, 2, &cost).is_err());
        // Both workers down at boundary 1: nobody left to lead.
        let all_down = ChaosCfg {
            faults: vec![
                FaultWindow { worker: 0, fail_at: 1, rejoin_at: 3 },
                FaultWindow { worker: 1, fail_at: 1, rejoin_at: 3 },
            ],
            ..ChaosCfg::default()
        };
        assert!(ChaosPlan::new(all_down, 2, &cost).is_err());
        let zero_reorder =
            ChaosCfg { reorder_window: 0, ..ChaosCfg::default() };
        assert!(ChaosPlan::new(zero_reorder, 2, &cost).is_err());
    }

    #[test]
    fn rto_defaults_from_cost_model() {
        let cost = CostModel { latency_s: 1e-3, bandwidth_bps: 1e9 };
        let cfg = ChaosCfg {
            drop_prob: 0.5,
            max_retries: 1,
            seed: 2,
            ..ChaosCfg::default()
        };
        let p = ChaosPlan::new(cfg, 2, &cost).unwrap();
        assert!((p.rto_s - cost.retransmit_timeout()).abs() < 1e-15);
    }

    #[test]
    fn spec_string_round_trip() {
        let cfg: ChaosCfg =
            "seed=7, delay=2ms, delay-max=20ms, drop=0.05, rto=1ms, \
             retries=5, reorder=4, straggle=1:4.0, fault=2@3..5, fault=0@9"
                .parse()
                .unwrap();
        assert_eq!(cfg.seed, 7);
        assert!((cfg.delay_mean_s - 2e-3).abs() < 1e-12);
        assert!((cfg.delay_max_s - 20e-3).abs() < 1e-12);
        assert!((cfg.drop_prob - 0.05).abs() < 1e-12);
        assert!((cfg.rto_s - 1e-3).abs() < 1e-12);
        assert_eq!(cfg.max_retries, 5);
        assert_eq!(cfg.reorder_window, 4);
        assert_eq!(cfg.stragglers, vec![(1, 4.0)]);
        assert_eq!(
            cfg.faults,
            vec![
                FaultWindow { worker: 2, fail_at: 3, rejoin_at: 5 },
                FaultWindow { worker: 0, fail_at: 9, rejoin_at: u64::MAX },
            ]
        );
        assert_eq!("".parse::<ChaosCfg>().unwrap(), ChaosCfg::default());
        assert_eq!("on".parse::<ChaosCfg>().unwrap(), ChaosCfg::default());
    }

    #[test]
    fn spec_string_errors_name_the_problem() {
        let e = "nope=1".parse::<ChaosCfg>().unwrap_err();
        assert!(e.contains("unknown key"), "{e}");
        let e = "delay=xyz".parse::<ChaosCfg>().unwrap_err();
        assert!(e.contains("duration"), "{e}");
        let e = "straggle=9".parse::<ChaosCfg>().unwrap_err();
        assert!(e.contains("worker:factor"), "{e}");
        let e = "fault=2".parse::<ChaosCfg>().unwrap_err();
        assert!(e.contains("worker@fail"), "{e}");
        assert!("seed".parse::<ChaosCfg>().is_err());
    }

    #[test]
    fn spec_rejects_duplicate_stragglers_naming_the_worker() {
        let e = "straggle=1:4, straggle=1:2"
            .parse::<ChaosCfg>()
            .unwrap_err();
        assert!(e.contains("duplicate straggle"), "{e}");
        assert!(e.contains("worker 1"), "{e}");
        // Distinct workers stay fine.
        let cfg: ChaosCfg = "straggle=0:2, straggle=1:4".parse().unwrap();
        assert_eq!(cfg.stragglers, vec![(0, 2.0), (1, 4.0)]);
    }

    #[test]
    fn spec_rejects_overlapping_fault_windows_naming_the_worker() {
        let e = "fault=2@1..5, fault=2@3..7".parse::<ChaosCfg>().unwrap_err();
        assert!(e.contains("overlapping fault windows"), "{e}");
        assert!(e.contains("worker 2"), "{e}");
        // A never-rejoining window overlaps everything after it.
        let e = "fault=0@2, fault=0@9..10".parse::<ChaosCfg>().unwrap_err();
        assert!(e.contains("worker 0"), "{e}");
        // Touching windows ([1,3) then [3,5)) and distinct workers are fine.
        let cfg: ChaosCfg =
            "fault=1@1..3, fault=1@3..5, fault=2@1..5".parse().unwrap();
        assert_eq!(cfg.faults.len(), 3);
    }

    #[test]
    fn plan_rejects_duplicate_stragglers_from_programmatic_cfgs() {
        // The TOML/builder path pushes entries directly into ChaosCfg,
        // bypassing FromStr — ChaosPlan::new must catch duplicates too.
        let dup = ChaosCfg {
            stragglers: vec![(1, 4.0), (1, 2.0)],
            ..ChaosCfg::default()
        };
        let e = ChaosPlan::new(dup, 4, &CostModel::free()).unwrap_err();
        assert!(e.to_string().contains("duplicate straggler"), "{e}");
        assert!(e.to_string().contains("worker 1"), "{e}");
    }

    #[test]
    fn duration_suffixes() {
        assert!((parse_secs("2ms").unwrap() - 2e-3).abs() < 1e-15);
        assert!((parse_secs("50us").unwrap() - 50e-6).abs() < 1e-18);
        assert!((parse_secs("0.5s").unwrap() - 0.5).abs() < 1e-15);
        assert!((parse_secs("0.25").unwrap() - 0.25).abs() < 1e-15);
        assert!(parse_secs("fast").is_err());
    }
}
