//! Simulated communication fabric.
//!
//! DESIGN.md §2: the paper's 32-node / 10 Gbps Ethernet testbed is replaced
//! by an in-process fabric that is *bit-exact* in what data moves (real
//! messages between worker threads, real ring-allreduce) and *analytic* in
//! what time passes (an α-β cost model integrated per worker as simulated
//! wall-clock). The accuracy experiments depend only on the former; the
//! timing tables (Table 2, Fig. 3 right axes) depend only on the latter.
//!
//! The [`chaos`] module layers deterministic, seeded network degradation
//! (delays, drops with retransmit accounting, bounded reordering,
//! stragglers, fault windows with elastic membership) on top of the fabric.

pub mod chaos;
pub mod collectives;
pub mod cost;
pub mod fabric;

pub use chaos::{ChaosCfg, ChaosPlan, FaultWindow};
pub use collectives::{
    ring_allreduce_mean, ring_allreduce_mean_group,
    ring_allreduce_mean_group_c, ring_allreduce_mean_group_p,
};
pub use cost::{CostModel, WorkloadTiming};
pub use fabric::{Fabric, GossipMsg, Tiers};
