//! Runtime: load AOT artifacts (HLO text) and execute them via PJRT.
//!
//! - [`manifest`] — typed view of `artifacts/manifest.json` (what the AOT
//!   exporter produced: graphs, shapes, data descriptors, param packing).
//! - [`engine`] — the PJRT CPU execution engine (compile-once,
//!   execute-many, thread-safe) plus buffer plumbing.
//!
//! The interchange format is HLO *text* (`HloModuleProto::from_text_file`);
//! see DESIGN.md and /opt/xla-example/README.md for why serialized protos
//! from jax >= 0.5 are rejected by xla_extension 0.5.1.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, ExecHandle};
pub use manifest::{DataDesc, GraphInfo, Manifest, PresetInfo};

/// Default artifacts directory, overridable with `SLOWMO_ARTIFACTS`.
pub fn artifacts_dir() -> String {
    std::env::var("SLOWMO_ARTIFACTS").unwrap_or_else(|_| {
        // Walk up from cwd looking for an `artifacts/` dir so tests work
        // from both the workspace root and `rust/`.
        let mut dir = std::env::current_dir().unwrap_or_default();
        loop {
            let cand = dir.join("artifacts");
            if cand.join("manifest.json").exists() {
                return cand.to_string_lossy().into_owned();
            }
            if !dir.pop() {
                return "artifacts".to_string();
            }
        }
    })
}
