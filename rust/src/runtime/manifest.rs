//! Typed view of `artifacts/manifest.json`.

use crate::jsonx::{parse, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

/// One tensor in a graph signature.
#[derive(Clone, Debug, PartialEq)]
pub struct IoDesc {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoDesc {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One lowered graph (train or eval) and its signature.
#[derive(Clone, Debug)]
pub struct GraphInfo {
    pub file: String,
    pub inputs: Vec<IoDesc>,
    pub outputs: Vec<IoDesc>,
}

/// What the Rust data generator must synthesize for a preset.
#[derive(Clone, Debug, PartialEq)]
pub enum DataDesc {
    Lm { vocab: usize, seq_len: usize, batch: usize },
    Class { in_dim: usize, classes: usize, batch: usize },
    Image { hw: usize, in_ch: usize, classes: usize, batch: usize },
    Quad { dim: usize, cond: f64 },
}

impl DataDesc {
    pub fn batch(&self) -> usize {
        match self {
            DataDesc::Lm { batch, .. } => *batch,
            DataDesc::Class { batch, .. } => *batch,
            DataDesc::Image { batch, .. } => *batch,
            DataDesc::Quad { .. } => 1,
        }
    }

    /// Tokens (LM) or examples (classifiers) consumed per training step;
    /// used to normalize loss curves across presets.
    pub fn examples_per_step(&self) -> usize {
        match self {
            DataDesc::Lm { batch, seq_len, .. } => batch * seq_len,
            _ => self.batch(),
        }
    }
}

/// One model preset exported by `python -m compile.aot`.
#[derive(Clone, Debug)]
pub struct PresetInfo {
    pub name: String,
    pub family: String,
    pub flat_len: usize,
    pub raw_len: usize,
    pub init_file: String,
    pub data: DataDesc,
    pub train: GraphInfo,
    pub eval: GraphInfo,
}

/// Optimizer graphs for a given flat length d.
#[derive(Clone, Debug)]
pub struct OptimInfo {
    pub d: usize,
    pub graphs: BTreeMap<String, GraphInfo>, // nesterov/adam/slowmo/axpy
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: String,
    pub presets: BTreeMap<String, PresetInfo>,
    pub optim: BTreeMap<usize, OptimInfo>,
}

fn io_desc(j: &Json) -> Result<IoDesc> {
    let shape = j
        .get("shape")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| anyhow!("io desc missing shape"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad shape entry")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = j
        .get("dtype")
        .and_then(|d| d.as_str())
        .ok_or_else(|| anyhow!("io desc missing dtype"))?
        .to_string();
    Ok(IoDesc { shape, dtype })
}

fn graph_info(j: &Json) -> Result<GraphInfo> {
    let file = j
        .get("file")
        .and_then(|f| f.as_str())
        .ok_or_else(|| anyhow!("graph missing file"))?
        .to_string();
    let parse_ios = |key: &str| -> Result<Vec<IoDesc>> {
        j.get(key)
            .and_then(|x| x.as_arr())
            .ok_or_else(|| anyhow!("graph missing {key}"))?
            .iter()
            .map(io_desc)
            .collect()
    };
    Ok(GraphInfo {
        file,
        inputs: parse_ios("inputs")?,
        outputs: parse_ios("outputs")?,
    })
}

fn data_desc(j: &Json) -> Result<DataDesc> {
    let kind = j
        .get("kind")
        .and_then(|k| k.as_str())
        .ok_or_else(|| anyhow!("data missing kind"))?;
    let gu = |key: &str| -> Result<usize> {
        j.get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("data missing {key}"))
    };
    Ok(match kind {
        "lm" => DataDesc::Lm {
            vocab: gu("vocab")?,
            seq_len: gu("seq_len")?,
            batch: gu("batch")?,
        },
        "class" => DataDesc::Class {
            in_dim: gu("in_dim")?,
            classes: gu("classes")?,
            batch: gu("batch")?,
        },
        "image" => DataDesc::Image {
            hw: gu("hw")?,
            in_ch: gu("in_ch")?,
            classes: gu("classes")?,
            batch: gu("batch")?,
        },
        "quad" => DataDesc::Quad {
            dim: gu("dim")?,
            cond: j
                .get("cond")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow!("data missing cond"))?,
        },
        other => bail!("unknown data kind {other:?}"),
    })
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Self> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path}"))?;
        Self::from_json_text(&text, dir)
    }

    pub fn from_json_text(text: &str, dir: &str) -> Result<Self> {
        let j = parse(text).map_err(|e| anyhow!("{e}"))?;
        let mut presets = BTreeMap::new();
        for (name, pj) in j
            .get("presets")
            .and_then(|p| p.as_obj())
            .ok_or_else(|| anyhow!("manifest missing presets"))?
        {
            let info = PresetInfo {
                name: name.clone(),
                family: pj
                    .get("family")
                    .and_then(|f| f.as_str())
                    .ok_or_else(|| anyhow!("preset {name} missing family"))?
                    .to_string(),
                flat_len: pj
                    .get("flat_len")
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| anyhow!("preset {name} missing flat_len"))?,
                raw_len: pj
                    .get("raw_len")
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| anyhow!("preset {name} missing raw_len"))?,
                init_file: pj
                    .get("init_file")
                    .and_then(|v| v.as_str())
                    .unwrap_or_default()
                    .to_string(),
                data: data_desc(
                    pj.get("data")
                        .ok_or_else(|| anyhow!("preset {name} missing data"))?,
                )?,
                train: graph_info(
                    pj.get("train")
                        .ok_or_else(|| anyhow!("preset {name} missing train"))?,
                )?,
                eval: graph_info(
                    pj.get("eval")
                        .ok_or_else(|| anyhow!("preset {name} missing eval"))?,
                )?,
            };
            presets.insert(name.clone(), info);
        }
        let mut optim = BTreeMap::new();
        if let Some(om) = j.get("optim").and_then(|o| o.as_obj()) {
            for (dstr, oj) in om {
                let d: usize = dstr
                    .parse()
                    .map_err(|_| anyhow!("bad optim key {dstr}"))?;
                let mut graphs = BTreeMap::new();
                for (gname, gj) in oj
                    .as_obj()
                    .ok_or_else(|| anyhow!("optim {dstr} not an object"))?
                {
                    graphs.insert(gname.clone(), graph_info(gj)?);
                }
                optim.insert(d, OptimInfo { d, graphs });
            }
        }
        Ok(Manifest {
            dir: dir.to_string(),
            presets,
            optim,
        })
    }

    pub fn preset(&self, name: &str) -> Result<&PresetInfo> {
        self.presets
            .get(name)
            .ok_or_else(|| anyhow!("preset {name:?} not in manifest (run `make artifacts`)"))
    }

    pub fn optim_for(&self, d: usize) -> Result<&OptimInfo> {
        self.optim
            .get(&d)
            .ok_or_else(|| anyhow!("no optimizer graphs for d={d}"))
    }

    /// Load the exported initial parameter vector for a preset
    /// (little-endian f32 raw file).
    pub fn load_init(&self, preset: &PresetInfo) -> Result<Vec<f32>> {
        let path = format!("{}/{}", self.dir, preset.init_file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {path}"))?;
        if bytes.len() != preset.flat_len * 4 {
            bail!(
                "{path}: expected {} bytes, got {}",
                preset.flat_len * 4,
                bytes.len()
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "presets": {
        "p": {
          "family": "mlp", "flat_len": 256, "raw_len": 250,
          "init_file": "init.p.f32",
          "data": {"kind": "class", "in_dim": 8, "classes": 3, "batch": 4},
          "train": {"file": "p.train.hlo.txt",
                    "inputs": [{"index":0,"shape":[256],"dtype":"float32"},
                               {"index":1,"shape":[4,8],"dtype":"float32"},
                               {"index":2,"shape":[4],"dtype":"int32"}],
                    "outputs": [{"index":0,"shape":[],"dtype":"float32"},
                                {"index":1,"shape":[256],"dtype":"float32"}]},
          "eval": {"file": "p.eval.hlo.txt", "inputs": [], "outputs": []}
        }
      },
      "optim": {
        "256": {"axpy": {"file": "opt.axpy.d256.hlo.txt",
                          "inputs": [], "outputs": []}}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json_text(SAMPLE, "/tmp").unwrap();
        let p = m.preset("p").unwrap();
        assert_eq!(p.flat_len, 256);
        assert_eq!(p.train.inputs.len(), 3);
        assert_eq!(p.train.inputs[1].shape, vec![4, 8]);
        assert_eq!(p.train.outputs[0].elem_count(), 1); // rank-0 scalar
        assert_eq!(
            p.data,
            DataDesc::Class { in_dim: 8, classes: 3, batch: 4 }
        );
        assert!(m.optim_for(256).unwrap().graphs.contains_key("axpy"));
        assert!(m.optim_for(512).is_err());
        assert!(m.preset("nope").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::from_json_text("{}", ".").is_err());
        assert!(Manifest::from_json_text("[1]", ".").is_err());
        assert!(Manifest::from_json_text("not json", ".").is_err());
    }

    #[test]
    fn data_desc_examples_per_step() {
        let lm = DataDesc::Lm { vocab: 10, seq_len: 8, batch: 2 };
        assert_eq!(lm.examples_per_step(), 16);
        let c = DataDesc::Class { in_dim: 4, classes: 2, batch: 32 };
        assert_eq!(c.examples_per_step(), 32);
    }
}
