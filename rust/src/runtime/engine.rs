//! PJRT execution engine: compile HLO-text artifacts once, execute from
//! many worker threads.
//!
//! Thread-safety: the PJRT C API guarantees `PJRT_LoadedExecutable_Execute`
//! and buffer transfers are thread-safe; the rust wrapper types are raw
//! pointers and therefore `!Send` by default, so we wrap them in shim types
//! with explicit `unsafe impl Send + Sync`. Set `SLOWMO_PJRT_SERIALIZE=1`
//! to route every execute through a global mutex instead (diagnostic mode).

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::manifest::GraphInfo;

/// An argument to a compiled graph.
pub enum Arg<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

impl<'a> Arg<'a> {
    pub fn f32v(data: &'a [f32]) -> Self {
        Arg::F32(data, &[])
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Arg::F32(data, shape) => {
                let l = xla::Literal::vec1(data);
                if shape.is_empty() {
                    l
                } else {
                    let dims: Vec<i64> =
                        shape.iter().map(|&d| d as i64).collect();
                    l.reshape(&dims)?
                }
            }
            Arg::I32(data, shape) => {
                let l = xla::Literal::vec1(data);
                if shape.is_empty() {
                    l
                } else {
                    let dims: Vec<i64> =
                        shape.iter().map(|&d| d as i64).collect();
                    l.reshape(&dims)?
                }
            }
        };
        Ok(lit)
    }

    fn len(&self) -> usize {
        match self {
            Arg::F32(d, _) => d.len(),
            Arg::I32(d, _) => d.len(),
        }
    }
}

struct SharedClient(xla::PjRtClient);
unsafe impl Send for SharedClient {}
unsafe impl Sync for SharedClient {}

struct SharedExec(xla::PjRtLoadedExecutable);
unsafe impl Send for SharedExec {}
unsafe impl Sync for SharedExec {}

/// Handle to one compiled executable.
#[derive(Clone)]
pub struct ExecHandle {
    exec: Arc<SharedExec>,
    pub info: GraphInfo,
    serialize: bool,
}

static EXEC_LOCK: OnceLock<Mutex<()>> = OnceLock::new();

impl ExecHandle {
    /// Execute with the given args; returns the flattened f32 outputs
    /// (one `Vec<f32>` per output tensor; i32 outputs are converted).
    pub fn exec(&self, args: &[Arg]) -> Result<Vec<Vec<f32>>> {
        if args.len() != self.info.inputs.len() {
            bail!(
                "{}: expected {} args, got {}",
                self.info.file,
                self.info.inputs.len(),
                args.len()
            );
        }
        for (i, (a, want)) in args.iter().zip(&self.info.inputs).enumerate() {
            if a.len() != want.elem_count() {
                bail!(
                    "{}: arg {i} has {} elements, signature wants {} {:?}",
                    self.info.file,
                    a.len(),
                    want.elem_count(),
                    want.shape
                );
            }
        }
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|a| a.to_literal())
            .collect::<Result<_>>()?;
        let _guard = if self.serialize {
            Some(EXEC_LOCK.get_or_init(|| Mutex::new(())).lock().unwrap())
        } else {
            None
        };
        let result = self.exec.0.execute::<xla::Literal>(&literals)?;
        drop(_guard);
        let lit = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("empty execute result"))?
            .to_literal_sync()?;
        // Graphs are lowered with return_tuple=True: always a tuple.
        let parts = lit.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for (i, p) in parts.into_iter().enumerate() {
            let ty = p.ty()?;
            let v: Vec<f32> = match ty {
                xla::ElementType::F32 => p.to_vec::<f32>()?,
                xla::ElementType::S32 => p
                    .to_vec::<i32>()?
                    .into_iter()
                    .map(|x| x as f32)
                    .collect(),
                other => bail!("output {i}: unsupported dtype {other:?}"),
            };
            out.push(v);
        }
        Ok(out)
    }
}

/// Compile-once cache of executables for one artifacts directory.
pub struct Engine {
    client: SharedClient,
    dir: String,
    cache: Mutex<BTreeMap<String, ExecHandle>>,
    serialize: bool,
}

impl Engine {
    /// Create a PJRT CPU engine rooted at `dir` (the artifacts directory).
    pub fn cpu(dir: &str) -> Result<Arc<Self>> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(Arc::new(Self {
            client: SharedClient(client),
            dir: dir.to_string(),
            cache: Mutex::new(BTreeMap::new()),
            serialize: std::env::var("SLOWMO_PJRT_SERIALIZE").is_ok(),
        }))
    }

    pub fn platform(&self) -> String {
        self.client.0.platform_name()
    }

    /// Load + compile (or fetch from cache) the graph described by `info`.
    pub fn load(&self, info: &GraphInfo) -> Result<ExecHandle> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(h) = cache.get(&info.file) {
                return Ok(h.clone());
            }
        }
        let path = format!("{}/{}", self.dir, info.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exec = self
            .client
            .0
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path}: {e:?}"))
            .context("PJRT compile failed")?;
        let handle = ExecHandle {
            exec: Arc::new(SharedExec(exec)),
            info: info.clone(),
            serialize: self.serialize,
        };
        self.cache
            .lock()
            .unwrap()
            .insert(info.file.clone(), handle.clone());
        Ok(handle)
    }

    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    // Engine tests that need real artifacts live in rust/tests/ (integration
    // level); here we only test arg validation plumbing that doesn't need a
    // PJRT client.
    use super::*;

    #[test]
    fn arg_lengths() {
        let a = Arg::F32(&[1.0, 2.0], &[2]);
        assert_eq!(a.len(), 2);
        let b = Arg::I32(&[1, 2, 3, 4], &[2, 2]);
        assert_eq!(b.len(), 4);
    }
}
