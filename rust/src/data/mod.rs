//! Synthetic datasets and per-worker sharding.
//!
//! DESIGN.md §2: the paper's CIFAR-10/ImageNet/WMT'16 workloads are
//! replaced by synthetic tasks that preserve what SlowMo's behaviour
//! depends on — a non-convex model trained on *worker-sharded,
//! heterogeneous* data:
//!
//! - [`ClassTask`] — Gaussian class clusters in R^d with per-worker class
//!   skew (Dirichlet-style) controlling the inter-worker heterogeneity ζ².
//! - [`ImageTask`] — the same construction shaped as (hw, hw, ch) images
//!   with fixed per-class patterns (for the CNN preset).
//! - [`LmTask`] — a char stream from a seeded order-1 Markov chain, so the
//!   transformer has real sequential structure to learn; each worker reads
//!   a disjoint region of the stream.
//! - [`QuadTask`] — worker-specific quadratic centers + gradient noise for
//!   the Theorem-1 validation benches (ζ and σ are direct knobs).
//!
//! Everything derives from `(seed, worker, step)` via [`crate::rng::stream`]
//! so runs are bit-deterministic and two algorithms see identical batches.

use crate::rng::{stream, Xoshiro256};
use crate::runtime::DataDesc;

/// One training batch, already flattened for the PJRT engine.
#[derive(Clone, Debug)]
pub enum Batch {
    /// (x flattened [B*F...], y labels [B])
    Class { x: Vec<f32>, y: Vec<i32> },
    /// (tokens [B*S], targets [B*S])
    Lm { tokens: Vec<i32>, targets: Vec<i32> },
    /// (center [dim], noise [dim])
    Quad { center: Vec<f32>, noise: Vec<f32> },
}

/// A task hands out per-(worker, step) batches.
pub trait Task: Send + Sync {
    fn train_batch(&self, worker: usize, step: u64) -> Batch;
    /// Held-out batch (identical across workers so eval is comparable).
    fn eval_batch(&self, idx: u64) -> Batch;
    fn desc(&self) -> &DataDesc;
}

/// Build the right task for a preset's data descriptor.
pub fn task_for(desc: &DataDesc, m: usize, seed: u64,
                heterogeneity: f64) -> Box<dyn Task> {
    match desc {
        DataDesc::Class { .. } => {
            Box::new(ClassTask::new(desc.clone(), m, seed, heterogeneity))
        }
        DataDesc::Image { .. } => {
            Box::new(ImageTask::new(desc.clone(), m, seed, heterogeneity))
        }
        DataDesc::Lm { .. } => Box::new(LmTask::new(desc.clone(), seed)),
        DataDesc::Quad { .. } => {
            Box::new(QuadTask::new(desc.clone(), m, seed, heterogeneity, 0.1))
        }
    }
}

// ------------------------------------------------------------------ Class

/// Per-worker class-probability skew: worker i prefers classes near
/// `i * classes / m` with strength `het` (0 = iid shards, 1 = strongly
/// non-iid). This is the ζ² knob of Corollary 1.
fn class_probs(classes: usize, m: usize, worker: usize, het: f64) -> Vec<f64> {
    let uniform = 1.0 / classes as f64;
    let center = (worker * classes) as f64 / m.max(1) as f64;
    let mut p: Vec<f64> = (0..classes)
        .map(|c| {
            let mut dist = (c as f64 - center).abs();
            dist = dist.min(classes as f64 - dist); // circular distance
            let peak = (-dist * dist / (classes as f64 * 0.5)).exp();
            (1.0 - het) * uniform + het * peak
        })
        .collect();
    let total: f64 = p.iter().sum();
    for v in &mut p {
        *v /= total;
    }
    p
}

fn sample_class(probs: &[f64], rng: &mut Xoshiro256) -> usize {
    let u = rng.next_f64();
    let mut acc = 0.0;
    for (c, &p) in probs.iter().enumerate() {
        acc += p;
        if u < acc {
            return c;
        }
    }
    probs.len() - 1
}

pub struct ClassTask {
    desc: DataDesc,
    centers: Vec<Vec<f32>>, // per class, length in_dim
    probs: Vec<Vec<f64>>,   // per worker
    seed: u64,
    noise: f32,
}

impl ClassTask {
    pub fn new(desc: DataDesc, m: usize, seed: u64, het: f64) -> Self {
        let (in_dim, classes) = match &desc {
            DataDesc::Class { in_dim, classes, .. } => (*in_dim, *classes),
            _ => panic!("ClassTask needs a Class descriptor"),
        };
        let mut rng = stream(seed, "class-centers", 0, 0, 0);
        let sep = 2.0f32;
        let centers = (0..classes)
            .map(|_| {
                let mut c = vec![0.0; in_dim];
                rng.fill_normal(&mut c, sep / (in_dim as f32).sqrt());
                c
            })
            .collect();
        let probs = (0..m)
            .map(|w| class_probs(classes, m, w, het))
            .collect();
        Self { desc, centers, probs, seed, noise: 1.0 }
    }

    fn gen(&self, rng: &mut Xoshiro256, probs: &[f64]) -> Batch {
        let (in_dim, batch) = match &self.desc {
            DataDesc::Class { in_dim, batch, .. } => (*in_dim, *batch),
            _ => unreachable!(),
        };
        let mut x = Vec::with_capacity(batch * in_dim);
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            let c = sample_class(probs, rng);
            y.push(c as i32);
            // Noise scale calibrated so the Bayes-optimal margin is ~2σ:
            // the task is learnable but not saturated, keeping the
            // baseline/SlowMo accuracy gaps visible (paper Table 1 shape).
            let sigma = self.noise * 16.0 / (in_dim as f32).sqrt().max(1.0);
            for f in 0..in_dim {
                x.push(self.centers[c][f] + sigma * rng.normal_f32());
            }
        }
        Batch::Class { x, y }
    }
}

impl Task for ClassTask {
    fn train_batch(&self, worker: usize, step: u64) -> Batch {
        let mut rng = stream(self.seed, "class-train", worker as u64, step, 0);
        self.gen(&mut rng, &self.probs[worker])
    }

    fn eval_batch(&self, idx: u64) -> Batch {
        let mut rng = stream(self.seed, "class-eval", idx, 0, 0);
        let classes = self.centers.len();
        let uniform = vec![1.0 / classes as f64; classes];
        self.gen(&mut rng, &uniform)
    }

    fn desc(&self) -> &DataDesc {
        &self.desc
    }
}

// ------------------------------------------------------------------ Image

pub struct ImageTask {
    desc: DataDesc,
    patterns: Vec<Vec<f32>>, // per class, hw*hw*ch
    probs: Vec<Vec<f64>>,
    seed: u64,
}

impl ImageTask {
    pub fn new(desc: DataDesc, m: usize, seed: u64, het: f64) -> Self {
        let (hw, in_ch, classes) = match &desc {
            DataDesc::Image { hw, in_ch, classes, .. } => {
                (*hw, *in_ch, *classes)
            }
            _ => panic!("ImageTask needs an Image descriptor"),
        };
        let mut rng = stream(seed, "image-patterns", 0, 0, 0);
        let n = hw * hw * in_ch;
        let patterns = (0..classes)
            .map(|_| {
                let mut p = vec![0.0; n];
                rng.fill_normal(&mut p, 1.0);
                p
            })
            .collect();
        let probs = (0..m)
            .map(|w| class_probs(classes, m, w, het))
            .collect();
        Self { desc, patterns, probs, seed }
    }

    fn gen(&self, rng: &mut Xoshiro256, probs: &[f64]) -> Batch {
        let (hw, in_ch, batch) = match &self.desc {
            DataDesc::Image { hw, in_ch, batch, .. } => {
                (*hw, *in_ch, *batch)
            }
            _ => unreachable!(),
        };
        let n = hw * hw * in_ch;
        let mut x = Vec::with_capacity(batch * n);
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            let c = sample_class(probs, rng);
            y.push(c as i32);
            for f in 0..n {
                x.push(self.patterns[c][f] + 0.5 * rng.normal_f32());
            }
        }
        Batch::Class { x, y }
    }
}

impl Task for ImageTask {
    fn train_batch(&self, worker: usize, step: u64) -> Batch {
        let mut rng = stream(self.seed, "image-train", worker as u64, step, 0);
        self.gen(&mut rng, &self.probs[worker])
    }

    fn eval_batch(&self, idx: u64) -> Batch {
        let mut rng = stream(self.seed, "image-eval", idx, 0, 0);
        let classes = self.patterns.len();
        let uniform = vec![1.0 / classes as f64; classes];
        self.gen(&mut rng, &uniform)
    }

    fn desc(&self) -> &DataDesc {
        &self.desc
    }
}

// --------------------------------------------------------------------- LM

/// Order-1 Markov chain over the vocab with sparse, peaked transitions.
/// Entropy is well below log(V), so a model that learns the chain beats
/// the uniform baseline by a wide, measurable margin.
pub struct LmTask {
    desc: DataDesc,
    /// transitions[c] = list of (next_char, cumulative probability)
    transitions: Vec<Vec<(i32, f64)>>,
    seed: u64,
}

impl LmTask {
    pub fn new(desc: DataDesc, seed: u64) -> Self {
        let vocab = match &desc {
            DataDesc::Lm { vocab, .. } => *vocab,
            _ => panic!("LmTask needs an Lm descriptor"),
        };
        let mut rng = stream(seed, "lm-chain", 0, 0, 0);
        let fanout = 8.min(vocab);
        let transitions = (0..vocab)
            .map(|_| {
                // `fanout` successors with Zipf-ish weights.
                let mut succ: Vec<i32> = (0..fanout)
                    .map(|_| rng.below(vocab as u64) as i32)
                    .collect();
                succ.dedup();
                let weights: Vec<f64> = (0..succ.len())
                    .map(|r| 1.0 / (r as f64 + 1.0))
                    .collect();
                let total: f64 = weights.iter().sum();
                let mut acc = 0.0;
                succ.iter()
                    .zip(weights)
                    .map(|(&c, w)| {
                        acc += w / total;
                        (c, acc)
                    })
                    .collect()
            })
            .collect();
        Self { desc, transitions, seed }
    }

    fn next_char(&self, cur: i32, rng: &mut Xoshiro256) -> i32 {
        let row = &self.transitions[cur as usize];
        let u = rng.next_f64();
        for &(c, cum) in row {
            if u < cum {
                return c;
            }
        }
        row.last().map(|&(c, _)| c).unwrap_or(0)
    }

    fn gen(&self, rng: &mut Xoshiro256) -> Batch {
        let (vocab, seq, batch) = match &self.desc {
            DataDesc::Lm { vocab, seq_len, batch } => {
                (*vocab, *seq_len, *batch)
            }
            _ => unreachable!(),
        };
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut cur = rng.below(vocab as u64) as i32;
            for _ in 0..seq {
                tokens.push(cur);
                let nxt = self.next_char(cur, rng);
                targets.push(nxt);
                cur = nxt;
            }
        }
        Batch::Lm { tokens, targets }
    }
}

impl Task for LmTask {
    fn train_batch(&self, worker: usize, step: u64) -> Batch {
        let mut rng = stream(self.seed, "lm-train", worker as u64, step, 0);
        self.gen(&mut rng)
    }

    fn eval_batch(&self, idx: u64) -> Batch {
        let mut rng = stream(self.seed, "lm-eval", idx, 0, 0);
        self.gen(&mut rng)
    }

    fn desc(&self) -> &DataDesc {
        &self.desc
    }
}

// ------------------------------------------------------------------- Quad

pub struct QuadTask {
    desc: DataDesc,
    centers: Vec<Vec<f32>>, // per worker
    pub sigma: f32,
    seed: u64,
}

impl QuadTask {
    pub fn new(desc: DataDesc, m: usize, seed: u64, zeta: f64,
               sigma: f64) -> Self {
        let dim = match &desc {
            DataDesc::Quad { dim, .. } => *dim,
            _ => panic!("QuadTask needs a Quad descriptor"),
        };
        // Worker centers: shared optimum + per-worker offset of norm ~zeta.
        let mut base_rng = stream(seed, "quad-base", 0, 0, 0);
        let mut base = vec![0.0f32; dim];
        base_rng.fill_normal(&mut base, 1.0);
        let centers = (0..m)
            .map(|w| {
                let mut rng = stream(seed, "quad-center", w as u64, 0, 0);
                let mut c = base.clone();
                for v in c.iter_mut() {
                    *v += zeta as f32 * rng.normal_f32()
                        / (dim as f32).sqrt();
                }
                c
            })
            .collect();
        Self { desc, centers, sigma: sigma as f32, seed }
    }

    /// The global optimum (mean of worker centers) — the λ-weighted
    /// minimizer of the average objective.
    pub fn global_center(&self) -> Vec<f32> {
        let dim = self.centers[0].len();
        let mut out = vec![0.0f32; dim];
        for c in &self.centers {
            for (o, &v) in out.iter_mut().zip(c) {
                *o += v;
            }
        }
        let m = self.centers.len() as f32;
        for o in out.iter_mut() {
            *o /= m;
        }
        out
    }
}

impl Task for QuadTask {
    fn train_batch(&self, worker: usize, step: u64) -> Batch {
        let dim = self.centers[worker].len();
        let mut rng = stream(self.seed, "quad-noise", worker as u64, step, 0);
        let mut noise = vec![0.0f32; dim];
        rng.fill_normal(&mut noise, self.sigma / (dim as f32).sqrt());
        Batch::Quad {
            center: self.centers[worker].clone(),
            noise,
        }
    }

    fn eval_batch(&self, _idx: u64) -> Batch {
        Batch::Quad {
            center: self.global_center(),
            noise: vec![0.0; self.centers[0].len()],
        }
    }

    fn desc(&self) -> &DataDesc {
        &self.desc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn class_desc() -> DataDesc {
        DataDesc::Class { in_dim: 8, classes: 4, batch: 16 }
    }

    #[test]
    fn class_probs_sum_to_one_and_skew() {
        let p0 = class_probs(10, 4, 0, 0.9);
        let p2 = class_probs(10, 4, 2, 0.9);
        assert!((p0.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((p2.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_ne!(p0, p2);
        // het=0 => uniform
        let u = class_probs(10, 4, 1, 0.0);
        assert!(u.iter().all(|&x| (x - 0.1).abs() < 1e-12));
    }

    #[test]
    fn class_batches_deterministic_and_distinct() {
        let t = ClassTask::new(class_desc(), 4, 7, 0.5);
        let a = t.train_batch(0, 3);
        let b = t.train_batch(0, 3);
        let c = t.train_batch(1, 3);
        match (&a, &b, &c) {
            (Batch::Class { x: xa, y: ya }, Batch::Class { x: xb, y: yb },
             Batch::Class { x: xc, .. }) => {
                assert_eq!(xa, xb);
                assert_eq!(ya, yb);
                assert_ne!(xa, xc);
                assert_eq!(xa.len(), 16 * 8);
                assert_eq!(ya.len(), 16);
                assert!(ya.iter().all(|&y| (0..4).contains(&y)));
            }
            _ => panic!("wrong batch kind"),
        }
    }

    #[test]
    fn heterogeneity_skews_class_histogram() {
        let t = ClassTask::new(class_desc(), 2, 1, 0.95);
        let mut counts = [[0usize; 4]; 2];
        for w in 0..2 {
            for s in 0..50 {
                if let Batch::Class { y, .. } = t.train_batch(w, s) {
                    for lbl in y {
                        counts[w][lbl as usize] += 1;
                    }
                }
            }
        }
        // Worker 0 should prefer class 0 over worker 1's preference.
        assert!(counts[0][0] > counts[1][0]);
    }

    #[test]
    fn lm_batches_in_vocab_and_shifted() {
        let desc = DataDesc::Lm { vocab: 32, seq_len: 12, batch: 3 };
        let t = LmTask::new(desc, 5);
        match t.train_batch(0, 0) {
            Batch::Lm { tokens, targets } => {
                assert_eq!(tokens.len(), 36);
                assert_eq!(targets.len(), 36);
                assert!(tokens.iter().all(|&c| (0..32).contains(&c)));
                assert!(targets.iter().all(|&c| (0..32).contains(&c)));
                // Within a row, target[i] == token[i+1].
                for row in 0..3 {
                    for i in 0..11 {
                        assert_eq!(targets[row * 12 + i],
                                   tokens[row * 12 + i + 1]);
                    }
                }
            }
            _ => panic!(),
        }
    }

    #[test]
    fn lm_chain_is_learnable_not_uniform() {
        // Empirical successor entropy must be clearly below log2(V).
        let desc = DataDesc::Lm { vocab: 64, seq_len: 256, batch: 4 };
        let t = LmTask::new(desc, 9);
        let mut counts = std::collections::HashMap::new();
        for s in 0..8 {
            if let Batch::Lm { tokens, targets } = t.train_batch(0, s) {
                for (a, b) in tokens.iter().zip(&targets) {
                    *counts.entry((*a, *b)).or_insert(0usize) += 1;
                }
            }
        }
        // Distinct bigrams should be far fewer than V^2 (sparse chain).
        assert!(counts.len() < 64 * 12, "bigrams: {}", counts.len());
    }

    #[test]
    fn image_batches_shape() {
        let desc = DataDesc::Image { hw: 4, in_ch: 2, classes: 3, batch: 5 };
        let t = ImageTask::new(desc, 2, 3, 0.5);
        match t.train_batch(1, 0) {
            Batch::Class { x, y } => {
                assert_eq!(x.len(), 5 * 4 * 4 * 2);
                assert_eq!(y.len(), 5);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn quad_centers_heterogeneous_with_zeta() {
        let desc = DataDesc::Quad { dim: 64, cond: 10.0 };
        let t0 = QuadTask::new(desc.clone(), 4, 1, 0.0, 0.1);
        let t1 = QuadTask::new(desc, 4, 1, 5.0, 0.1);
        // zeta=0 -> identical centers; zeta>0 -> spread.
        assert_eq!(t0.centers[0], t0.centers[1]);
        assert_ne!(t1.centers[0], t1.centers[1]);
        let g = t1.global_center();
        assert_eq!(g.len(), 64);
    }

    #[test]
    fn quad_noise_scales_with_sigma() {
        let desc = DataDesc::Quad { dim: 256, cond: 10.0 };
        let t = QuadTask::new(desc, 1, 2, 0.0, 1.0);
        if let Batch::Quad { noise, .. } = t.train_batch(0, 0) {
            let norm = crate::util::norm(&noise);
            assert!(norm > 0.3 && norm < 3.0, "norm {norm}");
        } else {
            panic!();
        }
    }

    #[test]
    fn eval_batches_worker_independent() {
        let t = ClassTask::new(class_desc(), 4, 7, 0.9);
        let a = t.eval_batch(0);
        let b = t.eval_batch(0);
        match (a, b) {
            (Batch::Class { x: xa, .. }, Batch::Class { x: xb, .. }) => {
                assert_eq!(xa, xb)
            }
            _ => panic!(),
        }
    }

    #[test]
    fn task_for_dispatch() {
        let d = DataDesc::Lm { vocab: 8, seq_len: 4, batch: 1 };
        let t = task_for(&d, 2, 0, 0.0);
        assert_eq!(t.desc(), &d);
    }
}
