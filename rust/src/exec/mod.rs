//! Execution substrate: scoped worker threads, barriers, and the two
//! message-passing backends behind the fabric's execution-mode seam.
//!
//! The image ships no tokio; this workload (m worker loops + blocking PJRT
//! execute calls) maps naturally onto one OS thread per worker with
//! channel-based message passing. Two channel implementations back that:
//!
//! - [`Mailboxes`] — one std::mpsc queue per receiver. The `sim`
//!   backend's transport: simple, blocking receives park on a futex.
//! - [`LinkChannels`] — one FIFO queue per *directed link* with
//!   spin-then-yield receives and a fixed sender-id scan order. The
//!   `threaded` backend's transport: no futex round trip on the hot
//!   path, and the per-link FIFO + deterministic scan order keep
//!   order-sensitive math bit-identical across runs.
//!
//! [`Lanes`] wraps the two behind one API, selected by [`ExecMode`].

use std::collections::{HashMap, VecDeque};
use std::hash::Hash;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};

/// Which execution backend a run uses. Selected via
/// `TrainBuilder::exec`, `--exec` on the CLI, or the `[exec]` TOML table.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// The simulated fabric (default): real worker threads, mpsc
    /// mailboxes, α-β cost accounting for simulated time. Every
    /// bit-determinism contract is stated against this backend.
    #[default]
    Sim,
    /// The real-parallel backend: identical cost arithmetic (so results
    /// are bitwise-identical to `Sim` where the math is order-safe), but
    /// transfers ride per-link spin channels built for wall-clock
    /// throughput instead of mpsc mailboxes.
    Threaded,
}

impl ExecMode {
    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Sim => "sim",
            ExecMode::Threaded => "threaded",
        }
    }
}

impl std::fmt::Display for ExecMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for ExecMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "sim" => Ok(ExecMode::Sim),
            "threaded" => Ok(ExecMode::Threaded),
            other => Err(format!(
                "unknown exec mode {other:?} (expected \"sim\" or \
                 \"threaded\")"
            )),
        }
    }
}

/// Reusable cyclic barrier for `n` parties (std::sync::Barrier equivalent,
/// re-implemented so we can expose generation counts to tests).
pub struct Barrier {
    n: usize,
    state: Mutex<(usize, u64)>, // (arrived, generation)
    cv: Condvar,
}

impl Barrier {
    pub fn new(n: usize) -> Arc<Self> {
        assert!(n > 0);
        Arc::new(Self {
            n,
            state: Mutex::new((0, 0)),
            cv: Condvar::new(),
        })
    }

    /// Block until all `n` parties arrive. Returns true for exactly one
    /// "leader" per generation.
    pub fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        let gen = st.1;
        st.0 += 1;
        if st.0 == self.n {
            st.0 = 0;
            st.1 += 1;
            self.cv.notify_all();
            true
        } else {
            while st.1 == gen {
                st = self.cv.wait(st).unwrap();
            }
            false
        }
    }

    pub fn generation(&self) -> u64 {
        self.state.lock().unwrap().1
    }
}

/// Spawn `n` scoped worker threads running `f(worker_id)` and join them all,
/// propagating the first panic. Returns each worker's result in id order.
pub fn run_workers<T: Send>(n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let f = &f;
                scope.spawn(move || f(i))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("worker panicked"))
            .collect()
    })
}

/// A simple fixed-size thread pool for fire-and-forget jobs (used by the
/// bench harness to parallelize independent experiment cells).
pub struct ThreadPool {
    tx: Option<Sender<Box<dyn FnOnce() + Send>>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = channel::<Box<dyn FnOnce() + Send>>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let handles = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                std::thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            job();
                            let (lock, cv) = &*pending;
                            *lock.lock().unwrap() -= 1;
                            cv.notify_all();
                        }
                        Err(_) => return,
                    }
                })
            })
            .collect();
        Self {
            tx: Some(tx),
            handles,
            pending,
        }
    }

    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        let (lock, _) = &*self.pending;
        *lock.lock().unwrap() += 1;
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("pool thread died");
    }

    /// Block until every submitted job has completed.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Keyed mutable state shared across worker threads (per-link chaos
/// counters etc.): a lazily-populated map guarded by one mutex. A single
/// lock is plenty for the fabric's per-send access pattern and keeps the
/// access order deterministic per key (each key is only ever touched by
/// one sender thread).
pub struct KeyedState<K, V> {
    inner: Mutex<HashMap<K, V>>,
}

impl<K: Eq + Hash, V> KeyedState<K, V> {
    pub fn new() -> Self {
        Self { inner: Mutex::new(HashMap::new()) }
    }

    /// Run `f` on the entry for `key`, inserting `default()` first if the
    /// key is new.
    pub fn with_mut<R>(
        &self,
        key: K,
        default: impl FnOnce() -> V,
        f: impl FnOnce(&mut V) -> R,
    ) -> R {
        let mut map = self.inner.lock().unwrap();
        f(map.entry(key).or_insert_with(default))
    }
}

impl<K: Eq + Hash, V> Default for KeyedState<K, V> {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-worker mailboxes: `send(to, msg)` / `recv(worker)`. The fabric in
/// [`crate::net`] builds on this.
pub struct Mailboxes<T> {
    senders: Vec<Sender<T>>,
    receivers: Vec<Mutex<Receiver<T>>>,
    sent: AtomicUsize,
}

impl<T: Send> Mailboxes<T> {
    pub fn new(n: usize) -> Self {
        let mut senders = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(Mutex::new(rx));
        }
        Self {
            senders,
            receivers,
            sent: AtomicUsize::new(0),
        }
    }

    pub fn len(&self) -> usize {
        self.senders.len()
    }

    pub fn is_empty(&self) -> bool {
        self.senders.is_empty()
    }

    pub fn send(&self, to: usize, msg: T) {
        self.sent.fetch_add(1, Ordering::Relaxed);
        self.senders[to].send(msg).expect("receiver dropped");
    }

    /// Blocking receive for `worker`'s mailbox.
    pub fn recv(&self, worker: usize) -> T {
        self.receivers[worker]
            .lock()
            .unwrap()
            .recv()
            .expect("all senders dropped")
    }

    /// Non-blocking receive.
    pub fn try_recv(&self, worker: usize) -> Option<T> {
        self.receivers[worker].lock().unwrap().try_recv().ok()
    }

    /// Receive with a timeout; `None` if nothing arrived in time.
    pub fn recv_timeout(
        &self,
        worker: usize,
        timeout: std::time::Duration,
    ) -> Option<T> {
        self.receivers[worker]
            .lock()
            .unwrap()
            .recv_timeout(timeout)
            .ok()
    }

    /// Drain everything currently queued for `worker`.
    pub fn drain(&self, worker: usize) -> Vec<T> {
        let rx = self.receivers[worker].lock().unwrap();
        let mut out = Vec::new();
        while let Ok(m) = rx.try_recv() {
            out.push(m);
        }
        out
    }

    pub fn total_sent(&self) -> usize {
        self.sent.load(Ordering::Relaxed)
    }
}

/// How many empty scan passes a [`LinkChannels`] receive spins before
/// yielding the core. Small on purpose: on an oversubscribed machine
/// (more workers than cores) the sender needs the core to make progress,
/// so burning long spin loops is counterproductive.
const SPIN_BUDGET: u32 = 64;

/// Per-directed-link FIFO channels for `n` workers: the `threaded`
/// backend's transport.
///
/// Design constraints, in priority order:
///
/// 1. **Determinism by construction.** Each `(from, to)` link is its own
///    FIFO queue, and a receive scans its incoming links in ascending
///    sender-id order. Messages from one sender can therefore never be
///    observed out of program order, and when several senders race, the
///    winner is decided by sender id, not thread scheduling. (Where a
///    consumer merges messages from *multiple* senders into
///    order-sensitive f32 math — D-PSGD's in-degree-2 mixing — arrival
///    order already decides the result under `Mailboxes` too; the seam
///    adds no new nondeterminism.)
/// 2. **No futex on the hot path.** Receives spin on per-link atomic
///    counters ([`SPIN_BUDGET`] passes) and then `yield_now`, so the
///    common chunk-exchange pattern (the peer's send is in flight right
///    now) completes without parking the thread.
///
/// Queues are unbounded: OSGP sends tail messages to peers that may
/// already have finished their run, and a bounded queue would deadlock
/// the sender against a receiver that never drains.
pub struct LinkChannels<T> {
    n: usize,
    /// `queues[to * n + from]` — one receiver's incoming links are
    /// contiguous, so the scan walks one cache-friendly stripe.
    queues: Vec<Mutex<VecDeque<T>>>,
    /// Queue occupancy mirrors, checked before taking any lock.
    occupancy: Vec<AtomicUsize>,
    sent: AtomicUsize,
}

impl<T: Send> LinkChannels<T> {
    pub fn new(n: usize) -> Self {
        Self {
            n,
            queues: (0..n * n).map(|_| Mutex::new(VecDeque::new())).collect(),
            occupancy: (0..n * n).map(|_| AtomicUsize::new(0)).collect(),
            sent: AtomicUsize::new(0),
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn send(&self, from: usize, to: usize, msg: T) {
        let idx = to * self.n + from;
        self.queues[idx].lock().unwrap().push_back(msg);
        self.occupancy[idx].fetch_add(1, Ordering::Release);
        self.sent.fetch_add(1, Ordering::Relaxed);
    }

    /// Pop the next message for `worker` without blocking: links are
    /// scanned in ascending sender-id order, FIFO within each link. Only
    /// the owning worker thread may receive on its own slot (the same
    /// single-consumer contract the fabric's chunk stash relies on), so
    /// a non-zero occupancy reading guarantees the pop succeeds.
    pub fn try_recv(&self, worker: usize) -> Option<T> {
        for from in 0..self.n {
            let idx = worker * self.n + from;
            if self.occupancy[idx].load(Ordering::Acquire) > 0 {
                let msg = self.queues[idx].lock().unwrap().pop_front();
                debug_assert!(msg.is_some(), "occupancy lied");
                self.occupancy[idx].fetch_sub(1, Ordering::Release);
                return msg;
            }
        }
        None
    }

    /// Blocking receive: spin [`SPIN_BUDGET`] scan passes, then yield
    /// between passes. Panics never — the fabric owns both endpoints, so
    /// a message for an in-progress receive is always eventually sent
    /// (a peer that dies mid-run panics its own thread and the scoped
    /// join propagates it, matching `Mailboxes::recv` behavior).
    pub fn recv(&self, worker: usize) -> T {
        let mut spins = 0u32;
        loop {
            if let Some(msg) = self.try_recv(worker) {
                return msg;
            }
            spins = spins.saturating_add(1);
            if spins > SPIN_BUDGET {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
    }

    /// Receive with a timeout; `None` if nothing arrived in time.
    pub fn recv_timeout(
        &self,
        worker: usize,
        timeout: std::time::Duration,
    ) -> Option<T> {
        let deadline = std::time::Instant::now() + timeout;
        let mut spins = 0u32;
        loop {
            if let Some(msg) = self.try_recv(worker) {
                return Some(msg);
            }
            if std::time::Instant::now() >= deadline {
                return None;
            }
            spins = spins.saturating_add(1);
            if spins > SPIN_BUDGET {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
    }

    /// Drain everything currently queued for `worker`, in sender-id
    /// order (FIFO within each sender).
    pub fn drain(&self, worker: usize) -> Vec<T> {
        let mut out = Vec::new();
        for from in 0..self.n {
            let idx = worker * self.n + from;
            let taken = self.occupancy[idx].swap(0, Ordering::AcqRel);
            if taken > 0 {
                let mut q = self.queues[idx].lock().unwrap();
                out.extend(q.drain(..taken));
            }
        }
        out
    }

    pub fn total_sent(&self) -> usize {
        self.sent.load(Ordering::Relaxed)
    }
}

/// One message lane behind the execution-mode seam: the fabric holds a
/// `Lanes` per traffic class (gossip, collective chunks) and the chosen
/// [`ExecMode`] decides the transport underneath.
pub enum Lanes<T> {
    Sim(Mailboxes<T>),
    Threaded(LinkChannels<T>),
}

impl<T: Send> Lanes<T> {
    pub fn new(mode: ExecMode, n: usize) -> Self {
        match mode {
            ExecMode::Sim => Lanes::Sim(Mailboxes::new(n)),
            ExecMode::Threaded => Lanes::Threaded(LinkChannels::new(n)),
        }
    }

    pub fn mode(&self) -> ExecMode {
        match self {
            Lanes::Sim(_) => ExecMode::Sim,
            Lanes::Threaded(_) => ExecMode::Threaded,
        }
    }

    /// Send `msg` over the `from -> to` link (`from` is ignored by the
    /// sim transport, which queues per receiver only).
    pub fn send(&self, from: usize, to: usize, msg: T) {
        match self {
            Lanes::Sim(mb) => mb.send(to, msg),
            Lanes::Threaded(lc) => lc.send(from, to, msg),
        }
    }

    /// Blocking receive for `worker`.
    pub fn recv(&self, worker: usize) -> T {
        match self {
            Lanes::Sim(mb) => mb.recv(worker),
            Lanes::Threaded(lc) => lc.recv(worker),
        }
    }

    /// Receive with a timeout; `None` if nothing arrived in time.
    pub fn recv_timeout(
        &self,
        worker: usize,
        timeout: std::time::Duration,
    ) -> Option<T> {
        match self {
            Lanes::Sim(mb) => mb.recv_timeout(worker, timeout),
            Lanes::Threaded(lc) => lc.recv_timeout(worker, timeout),
        }
    }

    /// Drain everything currently queued for `worker`.
    pub fn drain(&self, worker: usize) -> Vec<T> {
        match self {
            Lanes::Sim(mb) => mb.drain(worker),
            Lanes::Threaded(lc) => lc.drain(worker),
        }
    }

    pub fn total_sent(&self) -> usize {
        match self {
            Lanes::Sim(mb) => mb.total_sent(),
            Lanes::Threaded(lc) => lc.total_sent(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn barrier_synchronizes() {
        let b = Barrier::new(4);
        let counter = AtomicU64::new(0);
        run_workers(4, |_| {
            counter.fetch_add(1, Ordering::SeqCst);
            b.wait();
            // After the barrier every thread must see all 4 increments.
            assert_eq!(counter.load(Ordering::SeqCst), 4);
        });
        assert_eq!(b.generation(), 1);
    }

    #[test]
    fn barrier_elects_single_leader_per_generation() {
        let b = Barrier::new(3);
        for _ in 0..5 {
            let leaders: usize = run_workers(3, |_| b.wait() as usize)
                .into_iter()
                .sum();
            assert_eq!(leaders, 1);
        }
        assert_eq!(b.generation(), 5);
    }

    #[test]
    fn run_workers_returns_in_id_order() {
        let out = run_workers(8, |i| i * 10);
        assert_eq!(out, vec![0, 10, 20, 30, 40, 50, 60, 70]);
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_wait_idle_on_empty_pool() {
        let pool = ThreadPool::new(2);
        pool.wait_idle(); // must not deadlock
    }

    #[test]
    fn keyed_state_counts_per_key() {
        let ks: KeyedState<(usize, usize), u64> = KeyedState::new();
        for _ in 0..3 {
            ks.with_mut((0, 1), || 0, |v| *v += 1);
        }
        ks.with_mut((1, 0), || 10, |v| *v += 1);
        assert_eq!(ks.with_mut((0, 1), || 0, |v| *v), 3);
        assert_eq!(ks.with_mut((1, 0), || 0, |v| *v), 11);
        assert_eq!(ks.with_mut((2, 2), || 7, |v| *v), 7);
    }

    #[test]
    fn keyed_state_cross_thread() {
        let ks: Arc<KeyedState<usize, u64>> = Arc::new(KeyedState::new());
        run_workers(4, |i| {
            for _ in 0..100 {
                ks.with_mut(i, || 0, |v| *v += 1);
            }
        });
        for i in 0..4 {
            assert_eq!(ks.with_mut(i, || 0, |v| *v), 100);
        }
    }

    #[test]
    fn mailboxes_point_to_point() {
        let mb: Mailboxes<(usize, u32)> = Mailboxes::new(3);
        mb.send(1, (0, 42));
        mb.send(1, (2, 43));
        mb.send(0, (1, 7));
        assert_eq!(mb.recv(1), (0, 42));
        assert_eq!(mb.recv(1), (2, 43));
        assert_eq!(mb.recv(0), (1, 7));
        assert_eq!(mb.total_sent(), 3);
        assert!(mb.try_recv(2).is_none());
    }

    #[test]
    fn mailboxes_drain() {
        let mb: Mailboxes<u32> = Mailboxes::new(2);
        for i in 0..5 {
            mb.send(0, i);
        }
        assert_eq!(mb.drain(0), vec![0, 1, 2, 3, 4]);
        assert!(mb.drain(0).is_empty());
    }

    #[test]
    fn mailboxes_cross_thread() {
        let mb: Arc<Mailboxes<usize>> = Arc::new(Mailboxes::new(4));
        run_workers(4, |i| {
            // Everyone sends its id to everyone (incl. self), then receives
            // exactly 4 messages.
            for to in 0..4 {
                mb.send(to, i);
            }
            let mut got: Vec<usize> = (0..4).map(|_| mb.recv(i)).collect();
            got.sort_unstable();
            assert_eq!(got, vec![0, 1, 2, 3]);
        });
    }

    #[test]
    fn exec_mode_parses_and_prints() {
        assert_eq!("sim".parse::<ExecMode>().unwrap(), ExecMode::Sim);
        assert_eq!(
            "threaded".parse::<ExecMode>().unwrap(),
            ExecMode::Threaded
        );
        assert_eq!(ExecMode::default(), ExecMode::Sim);
        assert_eq!(ExecMode::Threaded.to_string(), "threaded");
        let err = "turbo".parse::<ExecMode>().unwrap_err();
        assert!(err.contains("turbo"), "{err}");
    }

    #[test]
    fn link_channels_fifo_per_link() {
        let lc: LinkChannels<u32> = LinkChannels::new(3);
        lc.send(0, 1, 10);
        lc.send(0, 1, 11);
        lc.send(2, 1, 20);
        // Sender 0's messages come first (sender-id scan order), FIFO.
        assert_eq!(lc.recv(1), 10);
        assert_eq!(lc.recv(1), 11);
        assert_eq!(lc.recv(1), 20);
        assert!(lc.try_recv(1).is_none());
        assert_eq!(lc.total_sent(), 3);
    }

    #[test]
    fn link_channels_scan_order_is_sender_id() {
        let lc: LinkChannels<u32> = LinkChannels::new(4);
        // Queue in reverse sender order; receives come back sorted.
        for from in (0..4).rev() {
            lc.send(from, 0, from as u32);
        }
        let got: Vec<u32> = (0..4).map(|_| lc.recv(0)).collect();
        assert_eq!(got, vec![0, 1, 2, 3]);
    }

    #[test]
    fn link_channels_recv_timeout_expires() {
        let lc: LinkChannels<u32> = LinkChannels::new(2);
        let t0 = std::time::Instant::now();
        let got =
            lc.recv_timeout(0, std::time::Duration::from_millis(5));
        assert!(got.is_none());
        assert!(t0.elapsed() >= std::time::Duration::from_millis(5));
        lc.send(1, 0, 9);
        assert_eq!(
            lc.recv_timeout(0, std::time::Duration::from_millis(5)),
            Some(9)
        );
    }

    #[test]
    fn link_channels_drain_in_sender_order() {
        let lc: LinkChannels<u32> = LinkChannels::new(3);
        lc.send(2, 0, 20);
        lc.send(1, 0, 10);
        lc.send(1, 0, 11);
        assert_eq!(lc.drain(0), vec![10, 11, 20]);
        assert!(lc.drain(0).is_empty());
    }

    #[test]
    fn link_channels_cross_thread_blocking() {
        let lc: Arc<LinkChannels<usize>> = Arc::new(LinkChannels::new(4));
        let b = Barrier::new(4);
        run_workers(4, |i| {
            for to in 0..4 {
                lc.send(i, to, i);
            }
            // Once every send has landed, the scan order makes the
            // receive order exactly ascending sender ids. (Without the
            // barrier only per-sender FIFO would be guaranteed — a late
            // sender can lose the scan race to a higher id.)
            b.wait();
            let got: Vec<usize> = (0..4).map(|_| lc.recv(i)).collect();
            assert_eq!(got, vec![0, 1, 2, 3]);
        });
        assert_eq!(lc.total_sent(), 16);
    }

    #[test]
    fn link_channels_many_messages_stress() {
        let lc: Arc<LinkChannels<u64>> = Arc::new(LinkChannels::new(2));
        run_workers(2, |i| {
            let peer = 1 - i;
            for k in 0..1000u64 {
                lc.send(i, peer, k);
            }
            for k in 0..1000u64 {
                assert_eq!(lc.recv(i), k, "per-link FIFO broken");
            }
        });
    }

    #[test]
    fn lanes_dispatch_both_modes() {
        for mode in [ExecMode::Sim, ExecMode::Threaded] {
            let lanes: Lanes<u32> = Lanes::new(mode, 2);
            assert_eq!(lanes.mode(), mode);
            lanes.send(0, 1, 5);
            lanes.send(0, 1, 6);
            assert_eq!(lanes.recv(1), 5);
            assert_eq!(
                lanes.recv_timeout(
                    1,
                    std::time::Duration::from_millis(5)
                ),
                Some(6)
            );
            assert!(lanes
                .recv_timeout(1, std::time::Duration::from_millis(1))
                .is_none());
            lanes.send(1, 0, 7);
            assert_eq!(lanes.drain(0), vec![7]);
            assert_eq!(lanes.total_sent(), 3);
        }
    }
}
