//! Execution substrate: scoped worker threads, barriers, mailboxes.
//!
//! The image ships no tokio; this workload (m worker loops + blocking PJRT
//! execute calls) maps naturally onto one OS thread per worker with
//! channel-based message passing, which is what this module provides.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};

/// Reusable cyclic barrier for `n` parties (std::sync::Barrier equivalent,
/// re-implemented so we can expose generation counts to tests).
pub struct Barrier {
    n: usize,
    state: Mutex<(usize, u64)>, // (arrived, generation)
    cv: Condvar,
}

impl Barrier {
    pub fn new(n: usize) -> Arc<Self> {
        assert!(n > 0);
        Arc::new(Self {
            n,
            state: Mutex::new((0, 0)),
            cv: Condvar::new(),
        })
    }

    /// Block until all `n` parties arrive. Returns true for exactly one
    /// "leader" per generation.
    pub fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        let gen = st.1;
        st.0 += 1;
        if st.0 == self.n {
            st.0 = 0;
            st.1 += 1;
            self.cv.notify_all();
            true
        } else {
            while st.1 == gen {
                st = self.cv.wait(st).unwrap();
            }
            false
        }
    }

    pub fn generation(&self) -> u64 {
        self.state.lock().unwrap().1
    }
}

/// Spawn `n` scoped worker threads running `f(worker_id)` and join them all,
/// propagating the first panic. Returns each worker's result in id order.
pub fn run_workers<T: Send>(n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let f = &f;
                scope.spawn(move || f(i))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("worker panicked"))
            .collect()
    })
}

/// A simple fixed-size thread pool for fire-and-forget jobs (used by the
/// bench harness to parallelize independent experiment cells).
pub struct ThreadPool {
    tx: Option<Sender<Box<dyn FnOnce() + Send>>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = channel::<Box<dyn FnOnce() + Send>>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let handles = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                std::thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            job();
                            let (lock, cv) = &*pending;
                            *lock.lock().unwrap() -= 1;
                            cv.notify_all();
                        }
                        Err(_) => return,
                    }
                })
            })
            .collect();
        Self {
            tx: Some(tx),
            handles,
            pending,
        }
    }

    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        let (lock, _) = &*self.pending;
        *lock.lock().unwrap() += 1;
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("pool thread died");
    }

    /// Block until every submitted job has completed.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Keyed mutable state shared across worker threads (per-link chaos
/// counters etc.): a lazily-populated map guarded by one mutex. A single
/// lock is plenty for the fabric's per-send access pattern and keeps the
/// access order deterministic per key (each key is only ever touched by
/// one sender thread).
pub struct KeyedState<K, V> {
    inner: Mutex<HashMap<K, V>>,
}

impl<K: Eq + Hash, V> KeyedState<K, V> {
    pub fn new() -> Self {
        Self { inner: Mutex::new(HashMap::new()) }
    }

    /// Run `f` on the entry for `key`, inserting `default()` first if the
    /// key is new.
    pub fn with_mut<R>(
        &self,
        key: K,
        default: impl FnOnce() -> V,
        f: impl FnOnce(&mut V) -> R,
    ) -> R {
        let mut map = self.inner.lock().unwrap();
        f(map.entry(key).or_insert_with(default))
    }
}

impl<K: Eq + Hash, V> Default for KeyedState<K, V> {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-worker mailboxes: `send(to, msg)` / `recv(worker)`. The fabric in
/// [`crate::net`] builds on this.
pub struct Mailboxes<T> {
    senders: Vec<Sender<T>>,
    receivers: Vec<Mutex<Receiver<T>>>,
    sent: AtomicUsize,
}

impl<T: Send> Mailboxes<T> {
    pub fn new(n: usize) -> Self {
        let mut senders = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(Mutex::new(rx));
        }
        Self {
            senders,
            receivers,
            sent: AtomicUsize::new(0),
        }
    }

    pub fn len(&self) -> usize {
        self.senders.len()
    }

    pub fn is_empty(&self) -> bool {
        self.senders.is_empty()
    }

    pub fn send(&self, to: usize, msg: T) {
        self.sent.fetch_add(1, Ordering::Relaxed);
        self.senders[to].send(msg).expect("receiver dropped");
    }

    /// Blocking receive for `worker`'s mailbox.
    pub fn recv(&self, worker: usize) -> T {
        self.receivers[worker]
            .lock()
            .unwrap()
            .recv()
            .expect("all senders dropped")
    }

    /// Non-blocking receive.
    pub fn try_recv(&self, worker: usize) -> Option<T> {
        self.receivers[worker].lock().unwrap().try_recv().ok()
    }

    /// Receive with a timeout; `None` if nothing arrived in time.
    pub fn recv_timeout(
        &self,
        worker: usize,
        timeout: std::time::Duration,
    ) -> Option<T> {
        self.receivers[worker]
            .lock()
            .unwrap()
            .recv_timeout(timeout)
            .ok()
    }

    /// Drain everything currently queued for `worker`.
    pub fn drain(&self, worker: usize) -> Vec<T> {
        let rx = self.receivers[worker].lock().unwrap();
        let mut out = Vec::new();
        while let Ok(m) = rx.try_recv() {
            out.push(m);
        }
        out
    }

    pub fn total_sent(&self) -> usize {
        self.sent.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn barrier_synchronizes() {
        let b = Barrier::new(4);
        let counter = AtomicU64::new(0);
        run_workers(4, |_| {
            counter.fetch_add(1, Ordering::SeqCst);
            b.wait();
            // After the barrier every thread must see all 4 increments.
            assert_eq!(counter.load(Ordering::SeqCst), 4);
        });
        assert_eq!(b.generation(), 1);
    }

    #[test]
    fn barrier_elects_single_leader_per_generation() {
        let b = Barrier::new(3);
        for _ in 0..5 {
            let leaders: usize = run_workers(3, |_| b.wait() as usize)
                .into_iter()
                .sum();
            assert_eq!(leaders, 1);
        }
        assert_eq!(b.generation(), 5);
    }

    #[test]
    fn run_workers_returns_in_id_order() {
        let out = run_workers(8, |i| i * 10);
        assert_eq!(out, vec![0, 10, 20, 30, 40, 50, 60, 70]);
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_wait_idle_on_empty_pool() {
        let pool = ThreadPool::new(2);
        pool.wait_idle(); // must not deadlock
    }

    #[test]
    fn keyed_state_counts_per_key() {
        let ks: KeyedState<(usize, usize), u64> = KeyedState::new();
        for _ in 0..3 {
            ks.with_mut((0, 1), || 0, |v| *v += 1);
        }
        ks.with_mut((1, 0), || 10, |v| *v += 1);
        assert_eq!(ks.with_mut((0, 1), || 0, |v| *v), 3);
        assert_eq!(ks.with_mut((1, 0), || 0, |v| *v), 11);
        assert_eq!(ks.with_mut((2, 2), || 7, |v| *v), 7);
    }

    #[test]
    fn keyed_state_cross_thread() {
        let ks: Arc<KeyedState<usize, u64>> = Arc::new(KeyedState::new());
        run_workers(4, |i| {
            for _ in 0..100 {
                ks.with_mut(i, || 0, |v| *v += 1);
            }
        });
        for i in 0..4 {
            assert_eq!(ks.with_mut(i, || 0, |v| *v), 100);
        }
    }

    #[test]
    fn mailboxes_point_to_point() {
        let mb: Mailboxes<(usize, u32)> = Mailboxes::new(3);
        mb.send(1, (0, 42));
        mb.send(1, (2, 43));
        mb.send(0, (1, 7));
        assert_eq!(mb.recv(1), (0, 42));
        assert_eq!(mb.recv(1), (2, 43));
        assert_eq!(mb.recv(0), (1, 7));
        assert_eq!(mb.total_sent(), 3);
        assert!(mb.try_recv(2).is_none());
    }

    #[test]
    fn mailboxes_drain() {
        let mb: Mailboxes<u32> = Mailboxes::new(2);
        for i in 0..5 {
            mb.send(0, i);
        }
        assert_eq!(mb.drain(0), vec![0, 1, 2, 3, 4]);
        assert!(mb.drain(0).is_empty());
    }

    #[test]
    fn mailboxes_cross_thread() {
        let mb: Arc<Mailboxes<usize>> = Arc::new(Mailboxes::new(4));
        run_workers(4, |i| {
            // Everyone sends its id to everyone (incl. self), then receives
            // exactly 4 messages.
            for to in 0..4 {
                mb.send(to, i);
            }
            let mut got: Vec<usize> = (0..4).map(|_| mb.recv(i)).collect();
            got.sort_unstable();
            assert_eq!(got, vec![0, 1, 2, 3]);
        });
    }
}
