//! End-to-end driver: distributed training of a transformer language
//! model through the full three-layer stack.
//!
//! This is the repository's integration proof (DESIGN.md §4): the Layer-2
//! JAX transformer (with the Layer-1 Pallas optimizer kernels lowered into
//! the optimizer artifacts) is AOT-compiled to HLO, loaded by the Rust
//! Layer-3 coordinator, and trained with **Local Adam + SlowMo (BMUF-Adam,
//! the paper's WMT'16 configuration: maintain buffers, α=1)** across m
//! workers on a synthetic Markov-chain corpus — configured through the
//! canonical [`Session`]/`TrainBuilder` API, with a `RunObserver`
//! streaming progress mid-run. The loss curve is printed and appended to
//! results/e2e_lm.jsonl; EXPERIMENTS.md records a reference run.
//!
//! Run with:
//!   cargo run --release --example e2e_lm                (wmt-lm, ~2M)
//!   cargo run --release --example e2e_lm -- lm-tiny 120 (CI-speed)
//!   make e2e && cargo run --release --example e2e_lm -- lm-e2e (12.6M)

use slowmo::session::Session;
use slowmo::slowmo::{BufferStrategy, SlowMoCfg};
use slowmo::trainer::ProgressPrinter;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().cloned().unwrap_or_else(|| "wmt-lm".into());
    let steps: u64 = args
        .get(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| slowmo::util::env_u64("SLOWMO_EXAMPLE_STEPS", 240));
    let m: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);

    let session = match Session::open() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP: artifacts not found ({e}); run `make artifacts`");
            return Ok(());
        }
    };
    let info = session.manifest().preset(&preset)?;
    println!(
        "e2e: transformer LM preset={} ({} params), m={m}, {steps} steps",
        preset, info.raw_len
    );

    let tau = 12;
    let mut progress = ProgressPrinter { every: (steps / 10).max(1) };
    let r = session
        .train(&preset)
        .algo("local-adam")
        .workers(m)
        .steps(steps)
        .slowmo_cfg(
            SlowMoCfg::new(1.0, 0.5, tau)
                .with_buffers(BufferStrategy::Maintain),
        )
        .eval_every((steps / 10).max(1))
        .run_observed(&mut progress)?;

    println!("\ntraining loss curve (per outer iteration, τ={tau}):");
    for (step, loss) in &r.train_curve {
        let bar_len = ((loss / r.train_curve[0].1) * 50.0) as usize;
        println!("  step {:>5}  {:.4}  {}", step, loss,
                 "#".repeat(bar_len.min(60)));
    }
    println!("\nvalidation NLL / token accuracy:");
    for p in &r.eval_curve {
        println!(
            "  step {:>5}  nll {:.4}  token-acc {:.2}%",
            p.step, p.loss_mean, 100.0 * p.metric_mean
        );
    }
    let first = r.train_curve.first().map(|x| x.1).unwrap_or(f64::NAN);
    let last = r.train_curve.last().map(|x| x.1).unwrap_or(f64::NAN);
    println!("\ntrain loss: {first:.4} -> {last:.4}");
    println!("best val token accuracy: {:.2}%",
             100.0 * r.best_eval_metric);
    println!("sim time/iter: {}",
             slowmo::util::fmt_secs(r.sim_time_per_iter()));
    println!("wall time: {}", slowmo::util::fmt_secs(r.wall_time));
    r.append_jsonl("results/e2e_lm.jsonl")?;
    anyhow::ensure!(last < first, "loss did not decrease");
    println!("OK: loss decreased through the full 3-layer stack.");
    Ok(())
}
