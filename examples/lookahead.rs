//! Lookahead (Zhang et al. 2019) as a SlowMo special case (paper §2):
//! m=1 worker, β=0, α ∈ (0,1], base = SGD — "k steps forward, 1 step
//! back". Selected through the outer-optimizer registry: the `lookahead`
//! rule is one string key among `slowmo|avg|lookahead|nesterov|adam`
//! (see ROADMAP.md "Adding an outer optimizer").
//!
//! Every variant is one chained `TrainBuilder` off a shared [`Session`]
//! (the canonical entry point — the engine and model build are paid once
//! for all four runs).
//!
//! Run with:  cargo run --release --example lookahead
//! CI-sized:  SLOWMO_EXAMPLE_STEPS=30 cargo run --release --example lookahead

use slowmo::net::CostModel;
use slowmo::optim::kernels::InnerOpt;
use slowmo::session::Session;
use slowmo::trainer::Schedule;

fn run(
    session: &Session,
    steps: u64,
    outer: Option<&str>,
    label: &str,
) -> anyhow::Result<()> {
    let mut b = session
        .train("cifar-mlp")
        .algo("local")
        .inner(InnerOpt::Nesterov { beta0: 0.0, wd: 1e-4 })
        .workers(1) // single worker: the Lookahead regime
        .steps(steps)
        .seed(7)
        .schedule(Schedule::Const(0.08))
        .heterogeneity(0.0)
        .cost(CostModel::free());
    if let Some(spec) = outer {
        // k=6 fast steps per outer update; buffers kept across pulls.
        b = b
            .outer(spec)
            .tau(6)
            .buffers(slowmo::slowmo::BufferStrategy::Maintain);
    }
    let r = b.run()?;
    println!(
        "{label:<24} best train {:.4}   val acc {:.2}%",
        r.best_train_loss,
        100.0 * r.best_eval_metric
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let session = match Session::open() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP: artifacts not found ({e}); run `make artifacts`");
            return Ok(());
        }
    };
    let steps = slowmo::util::env_u64("SLOWMO_EXAMPLE_STEPS", 300);
    println!("Lookahead as SlowMo(m=1, beta=0) — paper §2 special case\n");
    // Plain SGD: no outer wrapper at all.
    run(&session, steps, None, "sgd")?;
    // Lookahead: pull back halfway (α=0.5) — the `lookahead` outer rule.
    run(&session, steps, Some("lookahead:0.5"), "lookahead(k=6, a=0.5)")?;
    // α=1 anchor: adopting the fast weights exactly (= plain SGD dynamics
    // in the m=1 case — sanity anchor, the `avg` fast path).
    run(&session, steps, Some("avg"), "avg(a=1, b=0)")?;
    // Slow momentum on a single node (BMUF-style m=1).
    run(&session, steps, Some("slowmo:0.5"), "slowmo(a=1, b=0.5)")?;
    Ok(())
}
