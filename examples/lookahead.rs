//! Lookahead (Zhang et al. 2019) as a SlowMo special case (paper §2):
//! m=1 worker, β=0, α ∈ (0,1], base = SGD — "k steps forward, 1 step
//! back". Compares plain SGD, Lookahead α=0.5 and SlowMo's α=1 anchor on
//! the CIFAR-analog task, single worker, no communication at all.
//!
//! Run with:  cargo run --release --example lookahead

use slowmo::net::CostModel;
use slowmo::optim::kernels::InnerOpt;
use slowmo::runtime::{artifacts_dir, Engine, Manifest};
use slowmo::slowmo::{BufferStrategy, SlowMoCfg};
use slowmo::trainer::{train, AlgoSpec, Schedule, TrainCfg};

fn run(
    manifest: &Manifest,
    engine: &Engine,
    slowmo: Option<SlowMoCfg>,
    label: &str,
) -> anyhow::Result<()> {
    let steps = 300;
    let cfg = TrainCfg {
        preset: "cifar-mlp".into(),
        m: 1, // single worker: the Lookahead regime
        steps,
        seed: 7,
        algo: AlgoSpec::Local(InnerOpt::Nesterov { beta0: 0.0, wd: 1e-4 }),
        slowmo,
        sched: Schedule::Const(0.08),
        heterogeneity: 0.0,
        eval_every: 0,
        eval_batches: 8,
        force_pjrt: false,
        native_kernels: true,
        cost: CostModel::free(),
        compute_time_s: 0.0,
        record_gradnorm: false,
    };
    let r = train(&cfg, manifest, Some(engine))?;
    println!(
        "{label:<24} best train {:.4}   val acc {:.2}%",
        r.best_train_loss,
        100.0 * r.best_eval_metric
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let engine = Engine::cpu(&dir)?;
    println!("Lookahead as SlowMo(m=1, beta=0) — paper §2 special case\n");
    // Plain SGD: τ=1, α=1, β=0 is the identity wrapper.
    run(&manifest, &engine, None, "sgd")?;
    // Lookahead: k=6 fast steps, pull back halfway (α=0.5).
    run(
        &manifest,
        &engine,
        Some(
            SlowMoCfg::new(0.5, 0.0, 6)
                .with_buffers(BufferStrategy::Maintain),
        ),
        "lookahead(k=6, a=0.5)",
    )?;
    // α=1 anchor: adopting the fast weights exactly (= plain SGD dynamics
    // in the m=1, β=0 case — sanity anchor).
    run(
        &manifest,
        &engine,
        Some(
            SlowMoCfg::new(1.0, 0.0, 6)
                .with_buffers(BufferStrategy::Maintain),
        ),
        "slowmo(a=1, b=0)",
    )?;
    // Slow momentum on a single node (BMUF-style m=1).
    run(
        &manifest,
        &engine,
        Some(
            SlowMoCfg::new(1.0, 0.5, 6)
                .with_buffers(BufferStrategy::Maintain),
        ),
        "slowmo(a=1, b=0.5)",
    )?;
    Ok(())
}
