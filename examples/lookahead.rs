//! Lookahead (Zhang et al. 2019) as a SlowMo special case (paper §2):
//! m=1 worker, β=0, α ∈ (0,1], base = SGD — "k steps forward, 1 step
//! back". Compares plain SGD, Lookahead α=0.5 and SlowMo's α=1 anchor on
//! the CIFAR-analog task, single worker, no communication at all.
//!
//! Every variant is one chained `TrainBuilder` off a shared [`Session`]
//! (the canonical entry point — the engine and model build are paid once
//! for all four runs).
//!
//! Run with:  cargo run --release --example lookahead

use slowmo::net::CostModel;
use slowmo::optim::kernels::InnerOpt;
use slowmo::session::Session;
use slowmo::slowmo::{BufferStrategy, SlowMoCfg};
use slowmo::trainer::Schedule;

fn run(
    session: &Session,
    slowmo: Option<SlowMoCfg>,
    label: &str,
) -> anyhow::Result<()> {
    let r = session
        .train("cifar-mlp")
        .algo("local")
        .inner(InnerOpt::Nesterov { beta0: 0.0, wd: 1e-4 })
        .workers(1) // single worker: the Lookahead regime
        .steps(300)
        .seed(7)
        .slowmo_opt(slowmo)
        .schedule(Schedule::Const(0.08))
        .heterogeneity(0.0)
        .cost(CostModel::free())
        .run()?;
    println!(
        "{label:<24} best train {:.4}   val acc {:.2}%",
        r.best_train_loss,
        100.0 * r.best_eval_metric
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let session = Session::open()?;
    println!("Lookahead as SlowMo(m=1, beta=0) — paper §2 special case\n");
    // Plain SGD: no wrapper at all.
    run(&session, None, "sgd")?;
    // Lookahead: k=6 fast steps, pull back halfway (α=0.5).
    run(
        &session,
        Some(
            SlowMoCfg::new(0.5, 0.0, 6)
                .with_buffers(BufferStrategy::Maintain),
        ),
        "lookahead(k=6, a=0.5)",
    )?;
    // α=1 anchor: adopting the fast weights exactly (= plain SGD dynamics
    // in the m=1, β=0 case — sanity anchor).
    run(
        &session,
        Some(
            SlowMoCfg::new(1.0, 0.0, 6)
                .with_buffers(BufferStrategy::Maintain),
        ),
        "slowmo(a=1, b=0)",
    )?;
    // Slow momentum on a single node (BMUF-style m=1).
    run(
        &session,
        Some(
            SlowMoCfg::new(1.0, 0.5, 6)
                .with_buffers(BufferStrategy::Maintain),
        ),
        "slowmo(a=1, b=0.5)",
    )?;
    Ok(())
}
