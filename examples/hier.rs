//! Hierarchical two-level SlowMo on a two-tier cluster: 4 workers split
//! into 2 groups with fast 10G intra-group links and a slow 1G / 0.5 ms
//! inter-group link — the BMUF cluster shape the paper's framework
//! generalizes.
//!
//! Demonstrates the hierarchy subsystem's three contracts:
//! 1. `groups("1")` is bit-identical to a run that never mentions groups
//!    (one group *is* the flat topology);
//! 2. the two-level reduce moves strictly fewer bytes over the slow
//!    inter-group links than flat SlowMo on the same cluster, and
//!    finishes sooner in simulated time;
//! 3. everything stays deterministic given the seed, and the intra-group
//!    fast average (`tau_inner`) composes on top.
//!
//! Runs on the engine-free quad fast path (no PJRT needed).
//!
//! Run with:  cargo run --release --example hier
//! CI-sized:  SLOWMO_EXAMPLE_STEPS=24 cargo run --release --example hier

use slowmo::net::CostModel;
use slowmo::optim::kernels::InnerOpt;
use slowmo::session::{Session, TrainBuilder};
use slowmo::trainer::TrainResult;

fn base(session: &Session, steps: u64) -> TrainBuilder<'_> {
    let inter = CostModel::ethernet_1g();
    session
        .train("quad")
        .algo("local")
        .inner(InnerOpt::Nesterov { beta0: 0.9, wd: 0.0 })
        .workers(4)
        .steps(steps)
        .seed(5)
        .slowmo(0.6, 8)
        .schedule(slowmo::trainer::Schedule::Const(0.2))
        .heterogeneity(1.0)
        .eval_batches(1)
        .cost(CostModel::ethernet_10g())
        .compute_time(2e-3)
        .record_params(true)
        .inter_link(inter.latency_s, inter.bandwidth_bps)
}

fn report(label: &str, r: &TrainResult) {
    println!(
        "{label:<16} best loss {:>9.4}   inter {:>9}   total {:>9}   sim {:>8}",
        r.best_train_loss,
        slowmo::util::fmt_bytes(r.bytes_inter),
        slowmo::util::fmt_bytes(r.bytes_sent),
        slowmo::util::fmt_secs(r.sim_time),
    );
}

fn main() -> anyhow::Result<()> {
    let session = match Session::native_only() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP: artifacts not found ({e}); run `make artifacts`");
            return Ok(());
        }
    };
    let steps = slowmo::util::env_u64("SLOWMO_EXAMPLE_STEPS", 64);
    println!(
        "quad / local+slowmo(t8,b0.6), m=4, {steps} steps, \
         10G intra / 1G inter\n"
    );

    // Contract 1: one group is the flat topology, bit for bit. (The
    // flat reference must not set the inter link — there are no
    // inter-group hops with g=1, so costs match too.)
    let flat = session
        .train("quad")
        .algo("local")
        .inner(InnerOpt::Nesterov { beta0: 0.9, wd: 0.0 })
        .workers(4)
        .steps(steps)
        .seed(5)
        .slowmo(0.6, 8)
        .schedule(slowmo::trainer::Schedule::Const(0.2))
        .heterogeneity(1.0)
        .eval_batches(1)
        .cost(CostModel::ethernet_10g())
        .compute_time(2e-3)
        .record_params(true)
        .run()?;
    report("flat (no groups)", &flat);
    let g1 = base(&session, steps).groups("1").run()?;
    assert_eq!(g1.final_params, flat.final_params, "g=1 must be flat");
    assert_eq!(g1.bytes_sent, flat.bytes_sent);
    assert_eq!(g1.bytes_inter, 0);

    // Contract 2: flat SlowMo on the tiered cluster vs the two-level
    // reduce — same steps, strictly less slow-link traffic, less time.
    let flat_tiered = base(&session, steps).groups_flat("2").run()?;
    report("flat on tiers", &flat_tiered);
    let hier = base(&session, steps).groups("2").run()?;
    report("hier g=2", &hier);
    assert!(
        hier.bytes_inter < flat_tiered.bytes_inter,
        "hier {} !< flat {}",
        hier.bytes_inter,
        flat_tiered.bytes_inter
    );
    assert!(
        hier.sim_time < flat_tiered.sim_time,
        "hier must win on the slow inter link: {} !< {}",
        hier.sim_time,
        flat_tiered.sim_time
    );

    // Contract 3: deterministic, and tau_inner composes.
    let again = base(&session, steps).groups("2").run()?;
    assert_eq!(again.final_params, hier.final_params, "nondeterministic");
    assert_eq!(again.bytes_inter, hier.bytes_inter);
    let ti = base(&session, steps).groups("2").tau_inner(2).run()?;
    report("hier g=2 ti=2", &ti);
    assert_eq!(
        ti.bytes_inter, hier.bytes_inter,
        "intra-group averages must not touch the slow links"
    );
    assert!(ti.bytes_sent > hier.bytes_sent);

    println!(
        "\nhierarchy cut slow-link traffic {} -> {} ({} total sim {} -> {})",
        slowmo::util::fmt_bytes(flat_tiered.bytes_inter),
        slowmo::util::fmt_bytes(hier.bytes_inter),
        hier.algo,
        slowmo::util::fmt_secs(flat_tiered.sim_time),
        slowmo::util::fmt_secs(hier.sim_time),
    );
    Ok(())
}
