//! Data-heterogeneity ablation: when does slow momentum help most?
//!
//! Corollary 1's bound degrades with the inter-worker gradient
//! heterogeneity ζ² (the O(mτ/T) term carries ζ²τ²). This example sweeps
//! the heterogeneity knob of the synthetic CIFAR-analog task for Local SGD
//! with and without SlowMo, showing the gap widening as shards become
//! non-iid — the regime the paper's experiments live in.
//!
//! Run with:  cargo run --release --example heterogeneity

use slowmo::net::CostModel;
use slowmo::optim::kernels::InnerOpt;
use slowmo::runtime::{artifacts_dir, Engine, Manifest};
use slowmo::slowmo::{BufferStrategy, SlowMoCfg};
use slowmo::trainer::{train, AlgoSpec, Schedule, TrainCfg};

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let engine = Engine::cpu(&dir)?;
    let steps = 240;
    let tau = 12;
    println!("Local SGD vs +SlowMo across data heterogeneity (m=4, τ=12)\n");
    println!("{:<6} {:>16} {:>16} {:>8}", "het", "acc(local)",
             "acc(+slowmo)", "gap");
    for &het in &[0.0, 0.5, 0.95] {
        let mut accs = Vec::new();
        for beta in [0.0f32, 0.7] {
            let slowmo = if beta == 0.0 {
                // β=0 == plain Local SGD (periodic averaging only).
                SlowMoCfg::new(1.0, 0.0, tau)
                    .with_buffers(BufferStrategy::Maintain)
            } else {
                SlowMoCfg::new(1.0, beta, tau)
            };
            let cfg = TrainCfg {
                preset: "cifar-mlp".into(),
                m: 4,
                steps,
                seed: 3,
                algo: AlgoSpec::Local(InnerOpt::Nesterov {
                    beta0: 0.9,
                    wd: 1e-4,
                }),
                slowmo: Some(slowmo),
                sched: Schedule::image_default(0.1, steps),
                heterogeneity: het,
                eval_every: 0,
                eval_batches: 8,
                force_pjrt: false,
                native_kernels: true,
                cost: CostModel::ethernet_10g(),
                compute_time_s: 0.0,
                record_gradnorm: false,
            };
            let r = train(&cfg, &manifest, Some(&engine))?;
            accs.push(r.best_eval_metric);
        }
        println!(
            "{:<6} {:>15.2}% {:>15.2}% {:>7.2}%",
            het,
            100.0 * accs[0],
            100.0 * accs[1],
            100.0 * (accs[1] - accs[0])
        );
    }
    Ok(())
}
