//! Data-heterogeneity ablation: when does slow momentum help most?
//!
//! Corollary 1's bound degrades with the inter-worker gradient
//! heterogeneity ζ² (the O(mτ/T) term carries ζ²τ²). This example sweeps
//! the heterogeneity knob of the synthetic CIFAR-analog task for Local SGD
//! with and without SlowMo, showing the gap widening as shards become
//! non-iid — the regime the paper's experiments live in.
//!
//! The sweep runs through one shared [`Session`] (the canonical entry
//! point): the model executor is built once and reused by all six cells.
//!
//! Run with:  cargo run --release --example heterogeneity

use slowmo::optim::kernels::InnerOpt;
use slowmo::session::Session;
use slowmo::slowmo::{BufferStrategy, SlowMoCfg};

fn main() -> anyhow::Result<()> {
    let session = match Session::open() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP: artifacts not found ({e}); run `make artifacts`");
            return Ok(());
        }
    };
    let steps = slowmo::util::env_u64("SLOWMO_EXAMPLE_STEPS", 240);
    let tau = 12;
    println!("Local SGD vs +SlowMo across data heterogeneity (m=4, τ=12)\n");
    println!("{:<6} {:>16} {:>16} {:>8}", "het", "acc(local)",
             "acc(+slowmo)", "gap");
    for &het in &[0.0, 0.5, 0.95] {
        let mut accs = Vec::new();
        for beta in [0.0f32, 0.7] {
            let slowmo = if beta == 0.0 {
                // β=0 == plain Local SGD (periodic averaging only).
                SlowMoCfg::new(1.0, 0.0, tau)
                    .with_buffers(BufferStrategy::Maintain)
            } else {
                SlowMoCfg::new(1.0, beta, tau)
            };
            let r = session
                .train("cifar-mlp")
                .algo("local")
                .inner(InnerOpt::Nesterov { beta0: 0.9, wd: 1e-4 })
                .workers(4)
                .steps(steps)
                .seed(3)
                .slowmo_cfg(slowmo)
                .heterogeneity(het)
                .run()?;
            accs.push(r.best_eval_metric);
        }
        println!(
            "{:<6} {:>15.2}% {:>15.2}% {:>7.2}%",
            het,
            100.0 * accs[0],
            100.0 * accs[1],
            100.0 * (accs[1] - accs[0])
        );
    }
    Ok(())
}
