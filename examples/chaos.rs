//! Degraded-network scenario: the same SlowMo run on a perfect fabric, a
//! chaotic-but-faultless fabric (delays, drops, reordering, a straggler),
//! and a chaotic fabric where a worker dies mid-run and rejoins two outer
//! boundaries later (elastic membership).
//!
//! Demonstrates the chaos fabric's two contracts:
//! 1. chaos without faults moves *simulated time only* — the final
//!    parameters are bit-identical to the calm run;
//! 2. everything is deterministic given the seed — two chaotic runs agree
//!    on parameters, byte counts, retransmit counts and simulated time.
//!
//! Runs on the engine-free quad fast path (no PJRT needed).
//!
//! Run with:  cargo run --release --example chaos

use slowmo::net::{ChaosCfg, CostModel};
use slowmo::optim::kernels::InnerOpt;
use slowmo::session::Session;
use slowmo::slowmo::SlowMoCfg;
use slowmo::trainer::{Schedule, TrainResult};

/// Delays + drops + bounded reordering + one 4x straggler — no faults.
fn degraded() -> ChaosCfg {
    "seed=7,delay=2ms,delay-max=20ms,drop=0.05,reorder=4,straggle=1:4.0"
        .parse()
        .expect("valid chaos spec")
}

/// Same, plus worker 2 failing at outer boundary 2 and rejoining at 4.
fn degraded_with_fault() -> ChaosCfg {
    "seed=7,delay=2ms,delay-max=20ms,drop=0.05,reorder=4,straggle=1:4.0,\
     fault=2@2..4"
        .parse()
        .expect("valid chaos spec")
}

fn run(
    session: &Session,
    algo: &str,
    chaos: Option<ChaosCfg>,
) -> anyhow::Result<TrainResult> {
    session
        .train("quad")
        .algo(algo)
        .inner(InnerOpt::Nesterov { beta0: 0.9, wd: 0.0 })
        .workers(4)
        .steps(64)
        .seed(3)
        .slowmo_cfg(SlowMoCfg::new(1.0, 0.6, 8))
        .schedule(Schedule::Const(0.2))
        .heterogeneity(1.0)
        .eval_batches(1)
        .cost(CostModel::ethernet_10g())
        .compute_time(2e-3)
        .record_params(true)
        .chaos_opt(chaos)
        .run()
}

fn report(label: &str, r: &TrainResult) {
    println!(
        "{label:<22} best loss {:>9.4}   sim {:>8}   sent {:>9}   retx {:>4}",
        r.best_train_loss,
        slowmo::util::fmt_secs(r.sim_time),
        slowmo::util::fmt_bytes(r.bytes_sent),
        r.retransmits,
    );
}

fn main() -> anyhow::Result<()> {
    let session = match Session::native_only() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP: artifacts not found ({e}); run `make artifacts`");
            return Ok(());
        }
    };

    // SGP exercises the gossip lane, so drops show up as retransmits;
    // the fault scenario needs the communication-free `local` base.
    let calm = run(&session, "sgp", None)?;
    let chaotic = run(&session, "sgp", Some(degraded()))?;
    let chaotic2 = run(&session, "sgp", Some(degraded()))?;
    let calm_local = run(&session, "local", None)?;
    let faulty = run(&session, "local", Some(degraded_with_fault()))?;

    println!("quad / +slowmo(t8,b0.6), m=4, 64 steps\n");
    report("sgp, perfect net", &calm);
    report("sgp, degraded net", &chaotic);
    report("local, perfect net", &calm_local);
    report("local, degraded+fault", &faulty);

    // Contract 1: faultless chaos only moves simulated time.
    assert_eq!(
        calm.final_params, chaotic.final_params,
        "chaos without faults must not change the math"
    );
    assert!(chaotic.sim_time > calm.sim_time);
    println!(
        "\nfaultless chaos: parameters bit-identical to the calm run; \
         simulated time {:.2}x",
        chaotic.sim_time / calm.sim_time
    );

    // Contract 2: same seed => bit-identical everything.
    assert_eq!(chaotic.final_params, chaotic2.final_params);
    assert_eq!(chaotic.sim_time, chaotic2.sim_time);
    assert_eq!(chaotic.bytes_sent, chaotic2.bytes_sent);
    assert_eq!(chaotic.retransmits, chaotic2.retransmits);
    println!(
        "same seed, second run: identical parameters, {} bytes, \
         {} retransmits, {:.6} s simulated — deterministic",
        chaotic2.bytes_sent, chaotic2.retransmits, chaotic2.sim_time
    );

    // The faulted run completed (no deadlock) with different math: the
    // outer averages at boundaries 2 and 3 were taken over 3 survivors and
    // worker 2 rejoined by pulling the averaged parameters at boundary 4.
    assert_ne!(calm_local.final_params, faulty.final_params);
    println!(
        "fault window: worker 2 out for boundaries 2-3, rejoined at 4; \
         run completed without deadlock"
    );
    Ok(())
}
