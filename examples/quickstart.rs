//! Quickstart: train a model with SGP + SlowMo through the canonical
//! session/builder API in a dozen lines.
//!
//! A [`Session`] loads the AOT artifacts and brings up the PJRT engine
//! once (models/kernels/inits are cached across runs); the fluent
//! `TrainBuilder` describes the run; a `RunObserver` streams progress
//! while it trains.
//!
//! Run with:  cargo run --release --example quickstart
//! Requires:  make artifacts   (AOT-lowers the JAX/Pallas graphs first)

use slowmo::session::Session;
use slowmo::trainer::ProgressPrinter;

fn main() -> anyhow::Result<()> {
    // 1. One Session per process: manifest + PJRT CPU engine + caches.
    let session = match Session::open() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP: artifacts not found ({e}); run `make artifacts`");
            return Ok(());
        }
    };
    println!("engine: {}",
             session.engine().expect("pjrt engine").platform());

    // 2. Describe the run: 4 workers running SGP (push-sum gossip over
    //    the exponential graph), wrapped in SlowMo with τ=12, β=0.7 —
    //    the paper's CIFAR-10 configuration. Everything not set here
    //    keeps a typed default (seed 0, auto LR schedule, 10G-Ethernet
    //    cost model, ...).
    let steps = slowmo::util::env_u64("SLOWMO_EXAMPLE_STEPS", 240);
    let mut progress = ProgressPrinter { every: (steps / 4).max(1) };
    let result = session
        .train("cifar-mlp")
        .algo("sgp")
        .slowmo(0.7, 12)
        .workers(4)
        .steps(steps)
        .heterogeneity(0.8)
        .eval_every((steps / 4).max(1))
        .run_observed(&mut progress)?;

    // 3. Inspect.
    println!("\nvalidation curve (mean across {} workers):", result.m);
    for p in &result.eval_curve {
        println!(
            "  step {:>4}  loss {:.4}  acc {:.2}%  [{:.4}, {:.4}]",
            p.step,
            p.loss_mean,
            100.0 * p.metric_mean,
            p.loss_min,
            p.loss_max
        );
    }
    println!("\nbest training loss:  {:.4}", result.best_train_loss);
    println!("best validation acc: {:.2}%",
             100.0 * result.best_eval_metric);
    println!("fabric traffic:      {}",
             slowmo::util::fmt_bytes(result.bytes_sent));
    Ok(())
}
