//! Quickstart: train a model with SGP + SlowMo in ~30 lines.
//!
//! Run with:  cargo run --release --example quickstart
//! Requires:  make artifacts   (AOT-lowers the JAX/Pallas graphs first)

use slowmo::bench::Scale;
use slowmo::net::CostModel;
use slowmo::optim::kernels::InnerOpt;
use slowmo::runtime::{artifacts_dir, Engine, Manifest};
use slowmo::slowmo::SlowMoCfg;
use slowmo::trainer::{train, AlgoSpec, Schedule, TrainCfg};

fn main() -> anyhow::Result<()> {
    // 1. Load the AOT artifacts (HLO text lowered from JAX once, at build
    //    time) and bring up the PJRT CPU engine.
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let engine = Engine::cpu(&dir)?;
    println!("engine: {}", engine.platform());

    // 2. Configure: 4 workers running SGP (push-sum gossip over the
    //    exponential graph), wrapped in SlowMo with τ=12, β=0.7 —
    //    the paper's CIFAR-10 configuration.
    let steps = 240;
    let cfg = TrainCfg {
        preset: "cifar-mlp".into(),
        m: 4,
        steps,
        seed: 0,
        algo: AlgoSpec::Sgp(InnerOpt::Nesterov { beta0: 0.9, wd: 1e-4 }),
        slowmo: Some(SlowMoCfg::new(1.0, 0.7, 12)),
        sched: Schedule::image_default(0.1, steps),
        heterogeneity: 0.8,
        eval_every: 60,
        eval_batches: 8,
        force_pjrt: false,
        native_kernels: true,
        cost: CostModel::ethernet_10g(),
        compute_time_s: 0.0,
        record_gradnorm: false,
    };

    // 3. Train and inspect.
    let result = train(&cfg, &manifest, Some(&engine))?;
    println!("\nvalidation curve (mean across {} workers):", cfg.m);
    for p in &result.eval_curve {
        println!(
            "  step {:>4}  loss {:.4}  acc {:.2}%  [{:.4}, {:.4}]",
            p.step,
            p.loss_mean,
            100.0 * p.metric_mean,
            p.loss_min,
            p.loss_max
        );
    }
    println!("\nbest training loss:  {:.4}", result.best_train_loss);
    println!("best validation acc: {:.2}%",
             100.0 * result.best_eval_metric);
    println!("fabric traffic:      {}",
             slowmo::util::fmt_bytes(result.bytes_sent));
    Ok(())
}
