//! Communication compression on the byte/accuracy frontier: the same
//! SlowMo run under every built-in codec — raw f32, half-precision
//! quantization, top-k / random-k sparsification, 1-bit signsgd (with
//! and without error feedback) and the DeMo-style frequency-domain
//! `demo` codec — comparing bytes-on-wire, simulated time and final
//! loss.
//!
//! Demonstrates the compress subsystem's three contracts:
//! 1. `none` is bit-identical to a run that never mentions compression;
//! 2. byte accounting is wire-honest — lossy codecs strictly shrink
//!    `bytes_sent` and report the savings in `bytes_saved`;
//! 3. everything is deterministic given the seed (randk included: its
//!    index streams derive from the run seed).
//!
//! Runs on the engine-free quad fast path (no PJRT needed).
//!
//! Run with:  cargo run --release --example compress
//! CI-sized:  SLOWMO_EXAMPLE_STEPS=24 cargo run --release --example compress

use slowmo::net::CostModel;
use slowmo::optim::kernels::InnerOpt;
use slowmo::session::Session;
use slowmo::trainer::{Schedule, TrainResult};

fn run(
    session: &Session,
    steps: u64,
    compress: Option<&str>,
) -> anyhow::Result<TrainResult> {
    let mut b = session
        .train("quad")
        .algo("local")
        .inner(InnerOpt::Nesterov { beta0: 0.9, wd: 0.0 })
        .workers(4)
        .steps(steps)
        .seed(3)
        .slowmo(0.6, 8)
        .schedule(Schedule::Const(0.2))
        .heterogeneity(1.0)
        .eval_batches(1)
        .cost(CostModel::ethernet_10g())
        .compute_time(2e-3)
        .record_params(true);
    if let Some(spec) = compress {
        b = b.compress(spec);
    }
    b.run()
}

fn report(label: &str, r: &TrainResult) {
    println!(
        "{label:<14} best loss {:>9.4}   sent {:>9}   saved {:>9}   sim {:>8}",
        r.best_train_loss,
        slowmo::util::fmt_bytes(r.bytes_sent),
        slowmo::util::fmt_bytes(r.bytes_saved),
        slowmo::util::fmt_secs(r.sim_time),
    );
}

fn main() -> anyhow::Result<()> {
    let session = match Session::native_only() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP: artifacts not found ({e}); run `make artifacts`");
            return Ok(());
        }
    };
    let steps = slowmo::util::env_u64("SLOWMO_EXAMPLE_STEPS", 64);
    println!("quad / local+slowmo(t8,b0.6), m=4, {steps} steps\n");

    let raw = run(&session, steps, None)?;
    report("raw f32", &raw);

    // Contract 1: the explicit identity codec is bit-identical to a run
    // that never mentions compression.
    let none = run(&session, steps, Some("none"))?;
    assert_eq!(none.final_params, raw.final_params);
    assert_eq!(none.bytes_sent, raw.bytes_sent);
    assert_eq!(none.sim_time, raw.sim_time);

    // `ef:demo` is a hard error (demo already carries a per-link
    // residual); the registry names both codecs in the message.
    let err = match session
        .compress_registry()
        .parse("ef:demo:0.1")
        .and_then(|sel| session.compress_registry().build(&sel))
    {
        Ok(_) => panic!("ef:demo must be rejected"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("ef") && err.contains("demo"), "{err}");

    let mut prev_loss_note = String::new();
    for spec in ["fp16", "topk:0.1", "ef:topk:0.1", "randk:0.1",
                 "ef:signsgd", "demo:0.1"] {
        let r = run(&session, steps, Some(spec))?;
        report(spec, &r);
        // Contract 2: lossy codecs strictly cut bytes on the wire (and
        // the compressed run finishes sooner on the α-β network).
        assert!(
            r.bytes_sent < raw.bytes_sent,
            "{spec}: {} !< {}",
            r.bytes_sent,
            raw.bytes_sent
        );
        assert!(r.bytes_saved > 0, "{spec} reported no savings");
        assert!(r.sim_time < raw.sim_time, "{spec} not faster");
        // Contract 3: same seed, same everything.
        let again = run(&session, steps, Some(spec))?;
        assert_eq!(again.final_params, r.final_params, "{spec} nondet");
        assert_eq!(again.bytes_sent, r.bytes_sent, "{spec} nondet bytes");
        prev_loss_note = format!("{spec} loss {:.4}", r.best_train_loss);
    }
    println!(
        "\nall codecs deterministic; bytes strictly below raw f32 \
         ({prev_loss_note})"
    );
    Ok(())
}
