# Convenience targets referenced by the examples' SKIP messages, the
# test-suite skip notes, and ROADMAP.md.

.PHONY: artifacts e2e

# AOT-lower the JAX/Pallas model + optimizer graphs and the golden
# fixtures into artifacts/ (seed 1234 is the committed golden baseline;
# see ROADMAP.md "Testing"). Requires jax (Python side only; the Rust
# training path never runs Python).
artifacts:
	python python/compile/aot.py --out-dir artifacts --golden-seed 1234

# Additionally export the ~12.6M-param end-to-end LM preset used by
# `cargo run --release --example e2e_lm -- lm-e2e`.
e2e: artifacts
	python python/compile/aot.py --out-dir artifacts --group e2e
