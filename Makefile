# Convenience targets referenced by the examples' SKIP messages, the
# test-suite skip notes, and ROADMAP.md.

.PHONY: artifacts e2e bench help

help:
	@echo "targets:"
	@echo "  artifacts  AOT-lower model/optimizer graphs into artifacts/"
	@echo "  e2e        also export the ~12.6M-param LM preset"
	@echo "  bench      hot-path micro-benchmarks -> results/BENCH_micro.json"
	@echo "             (fails on any kernel >25% slower than the previous"
	@echo "             checked-in run; SLOWMO_BENCH_TOL overrides)"
	@echo ""
	@echo "experiment sweeps (cargo run --release -- exp <id> --scale <s>):"
	@echo "  table1|table2|fig2|fig3|figb2|tableb23|tableb4|doubleavg|"
	@echo "  noaverage|outers|compress|hier|semisync|scale|theory|"
	@echo "  throughput|all"
	@echo "  (compress sweeps the demo frequency-domain codec vs topk et"
	@echo "  al.; scale sweeps m x topology under dense vs shared state)"
	@echo "scales: ci|quick|standard|full (exp default: quick; bench"
	@echo "honours SLOWMO_SCALE, default ci)"

# AOT-lower the JAX/Pallas model + optimizer graphs and the golden
# fixtures into artifacts/ (seed 1234 is the committed golden baseline;
# see ROADMAP.md "Testing"). Requires jax (Python side only; the Rust
# training path never runs Python).
artifacts:
	python python/compile/aot.py --out-dir artifacts --golden-seed 1234

# Additionally export the ~12.6M-param end-to-end LM preset used by
# `cargo run --release --example e2e_lm -- lm-e2e`.
e2e: artifacts
	python python/compile/aot.py --out-dir artifacts --group e2e

# Hot-path micro-benchmarks (ROADMAP item 5a): emits
# results/BENCH_micro.json (schema bench-micro/v2, validated in CI
# against results/BENCH_micro.schema.json). Scale via SLOWMO_SCALE.
bench:
	cargo bench --bench micro
